"""End-to-end driver: train a ~100M-param QAT (2-bit fake-quant forward)
llama-family model for a few hundred steps on synthetic data, with
checkpoints and restart.

    PYTHONPATH=src python examples/train_lowbit_lm.py [--steps 300]

Note: ~100M params on a single CPU core is slow but real; pass --tiny to
use the reduced config for a fast demo of the same driver.
"""

import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "qwen1.5-0.5b", "--steps", str(args.steps),
            "--batch", "4", "--seq", "128", "--lr", "3e-4",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    if args.tiny:
        argv += ["--reduced"]
    else:
        # ~100M-param slice of qwen1.5-0.5b geometry: fewer layers, full width
        from repro.configs import registry
        import repro.launch.train as T
        cfg = registry.get_config("qwen1.5-0.5b").replace(n_layers=4)
        orig = registry.get_config
        registry.get_config = lambda a: cfg if a == "qwen1.5-0.5b" else orig(a)
    return train.main(argv)


if __name__ == "__main__":
    sys.exit(main())
