"""Quickstart: the paper's technique in five steps on one matrix.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.mpgemm import mpgemm, precompute_tables
from repro.core.quantize import dequantize

rng = np.random.default_rng(0)
M, K, N = 32, 512, 1024

# 1) a high-precision activation matrix and a weight matrix
a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
w = jnp.asarray(rng.normal(size=(N, K)), jnp.float32)

# 2) quantize the weights to 2-bit packed codes on the symmetric odd grid
#    (Eq. 2 reinterpretation + Eq. 6 offline negation folding + packing)
qw = Q.quantize(w, bits=2, k_group=4, scheme="symmetric")
print(f"weights: {w.nbytes/1e6:.1f} MB fp32 -> "
      f"{qw.packed.nbytes/1e6:.2f} MB packed "
      f"({qw.storage_bits_per_weight():.0f} bits/weight)")

# 3) the DFG-transformed precompute: ONE table for every consumer of `a`
table = precompute_tables(a, k_group=4, table_quant="per_row")
print(f"table: {table.values.nbytes/1e6:.2f} MB int8 "
      f"(2^(K-1)={table.values.shape[-1]} entries/group after symmetrization)")

# 4) mpGEMM three ways — all mathematically the same product
y_ref = a @ dequantize(qw).T
for mode in ("dequant", "lut_xla"):
    y = mpgemm(a, qw, mode=mode, table=table if mode == "lut_xla" else None)
    err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
    print(f"mode={mode:8s} max rel err vs dequantized ref: {err:.2e}")

# 5) the Pallas LUT Tensor Core kernel (interpret mode on CPU)
y = mpgemm(a, qw, mode="lut_pallas", interpret=True)
err = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
print(f"mode=lut_pallas (kernel) max rel err: {err:.2e}")

# 6) the fused precompute→lookup pipeline (§3.1.1): one kernel, the table is
#    rebuilt in-VMEM from the activation block and never written to HBM
y_fused = mpgemm(a, qw, mode="lut_pallas", fusion="fused", interpret=True)
err = float(jnp.max(jnp.abs(y_fused - y_ref)) / jnp.max(jnp.abs(y_ref)))
print(f"mode=lut_pallas fusion=fused max rel err: {err:.2e} "
      f"(table HBM bytes: 0)")
print("OK")
