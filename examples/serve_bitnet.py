"""Serve the paper's BitNet b1.58 model (ternary weights, LUT mpGEMM) with
batched requests through the continuous-batching engine.

    PYTHONPATH=src python examples/serve_bitnet.py
"""

import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.exit(serve.main([
        "--arch", "paper-bitnet-3b", "--reduced",
        "--requests", "10", "--max-new", "16", "--max-batch", "4",
        "--mode", "lut_xla", "--weight-bits", "2",
    ]))
