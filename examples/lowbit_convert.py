"""Convert a float model to the packed low-bit serving format and verify:
compression ratio + output agreement, across W4/W2/ternary.

    PYTHONPATH=src python examples/lowbit_convert.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.models.quantized import quantize_params, quantized_bytes

cfg = registry.get_reduced("llama3.2-3b").replace(activation_dtype=jnp.float32)
params = api.init_params(jax.random.key(0), cfg)
fp_bytes = quantized_bytes(params)
batch = {"tokens": jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
ref_logits, _, _ = api.forward(params, batch, cfg.replace(quant=None))
ref = np.asarray(ref_logits, np.float32)

def _proj_bytes(tree):
    """Bytes of quantizable projections only (embed/norms excluded)."""
    import jax.tree_util as jtu
    total = 0
    for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
        p = jtu.keystr(path)
        if "embed" in p or "norm" in p or "router" in p:
            continue
        if hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


fp_proj = _proj_bytes(params)
print(f"float params: {fp_bytes/1e6:.2f} MB ({fp_proj/1e6:.2f} MB projections)")
print("bits,scheme,total_MB,proj_compression,logit_corr")
for bits, scheme in [(4, "symmetric"), (2, "symmetric"), (2, "ternary")]:
    c = cfg.with_quant(weight_bits=bits, scheme=scheme)
    qp = quantize_params(params, c.quant)
    qb = quantized_bytes(qp)
    logits, _, _ = api.forward(qp, batch, c)
    got = np.asarray(logits, np.float32)
    corr = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    print(f"{bits},{scheme},{qb/1e6:.2f},"
          f"{fp_proj/_proj_bytes(qp):.1f}x,{corr:.4f}")
print("OK")
