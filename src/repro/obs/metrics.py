"""Typed metrics registry: counters, gauges, bounded-reservoir histograms.

Design constraints (serving hot path):

  * **Bounded memory.** A long-lived engine observes millions of chunk
    latencies; the histogram keeps an exact ``count``/``sum``/``min``/``max``
    plus a fixed-size reservoir (Vitter's algorithm R) for percentiles, so
    memory is O(reservoir) however long the engine lives — replacing the
    unbounded ``chunk_latencies`` list the engine used to grow forever.
  * **Thread-safe.** The admit loop, stats scrapes, and a future HTTP
    front-end touch the same registry; every instrument takes a per-
    instrument lock (ns-scale, uncontended) and the registry locks only
    get-or-create.
  * **Interpolated percentiles.** ``percentile(p)`` linearly interpolates
    between closest ranks — nearest-rank on a 3-sample list reported p50 as
    the *second-largest* sample, which is what ``engine.stats()`` shipped
    before this module.

Exposition: ``registry.snapshot()`` is a JSON-able dict;
``registry.prometheus_text()`` is the Prometheus text format (counters get
the ``_total`` convention applied by the caller's naming; histograms export
count/sum plus quantile gauges).
"""

from __future__ import annotations

import json
import math
import random
import threading
from typing import Dict, List, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "export_stats"]

_NAME_OK = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


def _check_name(name: str) -> str:
    if not name or name[0].isdigit() or not set(name) <= _NAME_OK:
        raise ValueError(f"invalid metric name {name!r} (want Prometheus "
                         "[a-zA-Z_:][a-zA-Z0-9_:]*)")
    return name


class _Instrument:
    kind = "?"

    def __init__(self, name: str, help: str = "", unit: str = ""):
        self.name = _check_name(name)
        self.help = help
        self.unit = unit
        self._lock = threading.Lock()


class Counter(_Instrument):
    """Monotonically increasing count. ``inc()`` with a negative delta
    raises — a decreasing counter is a bug, use a Gauge."""

    kind = "counter"

    def __init__(self, name, help="", unit=""):
        super().__init__(name, help, unit)
        self._value = 0

    def inc(self, n: Union[int, float] = 1):
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value

    def reset(self):
        with self._lock:
            self._value = 0

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge(_Instrument):
    """Last-written value (set/add; may go down)."""

    kind = "gauge"

    def __init__(self, name, help="", unit=""):
        super().__init__(name, help, unit)
        self._value = 0.0

    def set(self, v: float):
        with self._lock:
            self._value = v

    def add(self, v: float):
        with self._lock:
            self._value += v

    @property
    def value(self):
        with self._lock:
            return self._value

    def reset(self):
        self.set(0.0)

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Histogram(_Instrument):
    """Exact count/sum/min/max + fixed-size reservoir for percentiles.

    Reservoir sampling (algorithm R) keeps a uniform sample of everything
    ever observed, so percentiles stay representative of the whole run, not
    just the newest window, while memory stays O(reservoir_size). The RNG is
    seeded per-instrument for reproducible snapshots in tests.
    """

    kind = "histogram"

    def __init__(self, name, help="", unit="", reservoir_size: int = 1024,
                 seed: int = 0):
        super().__init__(name, help, unit)
        if reservoir_size < 1:
            raise ValueError(f"histogram {name}: reservoir_size must be >= 1")
        self.reservoir_size = reservoir_size
        self._rng = random.Random(seed ^ hash(name) & 0xFFFFFFFF)
        self._res: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float):
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if len(self._res) < self.reservoir_size:
                self._res.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < self.reservoir_size:
                    self._res[j] = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def total(self) -> float:
        """Exact sum of every observation (not reservoir-sampled)."""
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        """Linearly-interpolated percentile over the reservoir, ``p`` in
        [0, 1]. Small samples interpolate between closest ranks (numpy
        'linear' convention) instead of snapping to a single sample."""
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"percentile wants p in [0,1], got {p}")
        with self._lock:
            xs = sorted(self._res)
        if not xs:
            return 0.0
        rank = p * (len(xs) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def reset(self):
        with self._lock:
            self._res = []
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self._count, self._sum
            mn = self._min if self._count else 0.0
            mx = self._max if self._count else 0.0
        return {
            "type": self.kind, "count": count, "sum": total,
            "min": mn, "max": mx,
            "mean": total / count if count else 0.0,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


class MetricsRegistry:
    """Get-or-create instrument store with JSON + Prometheus exposition.

    ``common_labels`` (e.g. ``host="3"`` on a mesh'd run) are attached to
    every exposed series, so multi-host snapshots merge without collisions.
    Re-registering a name with a different instrument kind raises — a
    counter silently shadowing a histogram is how metrics go quietly wrong.
    """

    def __init__(self, common_labels: Optional[Dict[str, str]] = None):
        self._lock = threading.Lock()
        self._instruments: Dict[str, _Instrument] = {}
        self.common_labels: Dict[str, str] = dict(common_labels or {})

    def set_common_labels(self, **labels: str):
        self.common_labels.update({k: str(v) for k, v in labels.items()})

    def _get_or_create(self, cls, name, help, unit, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help=help, unit=unit, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}, "
                    f"requested {cls.kind}")
            return inst

    def counter(self, name, help="", unit="") -> Counter:
        return self._get_or_create(Counter, name, help, unit)

    def gauge(self, name, help="", unit="") -> Gauge:
        return self._get_or_create(Gauge, name, help, unit)

    def histogram(self, name, help="", unit="",
                  reservoir_size: int = 1024) -> Histogram:
        return self._get_or_create(Histogram, name, help, unit,
                                   reservoir_size=reservoir_size)

    def get(self, name) -> Optional[_Instrument]:
        with self._lock:
            return self._instruments.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def reset(self, prefix: str = ""):
        """Reset every instrument whose name starts with ``prefix`` (all by
        default). Instruments stay registered — engine.reset() zeroes its
        series without orphaning scrapers holding instrument handles."""
        with self._lock:
            insts = [i for n, i in self._instruments.items()
                     if n.startswith(prefix)]
        for i in insts:
            i.reset()

    # -- exposition -------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able {labels, metrics: {name: {...}}} snapshot."""
        with self._lock:
            insts = dict(self._instruments)
        return {
            "labels": dict(self.common_labels),
            "metrics": {n: i.snapshot() for n, i in sorted(insts.items())},
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def prometheus_text(self) -> str:
        """Prometheus text exposition (one HELP/TYPE block per series)."""
        labels = ",".join(f'{k}="{v}"'
                          for k, v in sorted(self.common_labels.items()))
        lb = f"{{{labels}}}" if labels else ""

        def qlb(extra):
            items = sorted(self.common_labels.items()) + sorted(extra.items())
            body = ",".join(f'{k}="{v}"' for k, v in items)
            return f"{{{body}}}" if body else ""

        with self._lock:
            insts = sorted(self._instruments.items())
        out = []
        for name, inst in insts:
            if inst.help:
                out.append(f"# HELP {name} {inst.help}")
            if isinstance(inst, Histogram):
                out.append(f"# TYPE {name} summary")
                snap = inst.snapshot()
                for q, key in (("0.5", "p50"), ("0.95", "p95"),
                               ("0.99", "p99")):
                    out.append(f"{name}{qlb({'quantile': q})} {snap[key]}")
                out.append(f"{name}_sum{lb} {snap['sum']}")
                out.append(f"{name}_count{lb} {snap['count']}")
            else:
                out.append(f"# TYPE {name} {inst.kind}")
                out.append(f"{name}{lb} {inst.value}")
        return "\n".join(out) + "\n"


def export_stats(registry: MetricsRegistry, stats: dict,
                 prefix: str = "engine") -> int:
    """Mirror a nested numeric stats dict into registry gauges.

    ``engine.stats()`` keeps its dict schema (the benches and tests consume
    it directly); this helper flattens it into ``<prefix>_<path>`` gauges so
    the same numbers ride the Prometheus/JSON exposition. Non-numeric and
    None values are skipped. Returns the number of gauges written."""
    n = 0

    def walk(prefix, node):
        nonlocal n
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}_{k}", v)
            return
        if isinstance(node, bool) or node is None or isinstance(node, str):
            return
        if isinstance(node, (int, float)):
            registry.gauge(_sanitize(prefix)).set(float(node))
            n += 1

    def _sanitize(name):
        return "".join(c if c in _NAME_OK else "_" for c in name)

    walk(prefix, stats)
    return n
