"""Request-lifecycle tracer: Chrome-trace / Perfetto JSON span recording.

The span taxonomy (docs/OBSERVABILITY.md) follows one request through the
engine: ``admit`` → ``prefill_chunk``(s) → ``decode_chunk``(s) → retire,
with the request's whole lifetime drawn as an async span keyed by uid.

Overhead contract (gated by ``benchmarks/bench_telemetry.py``):

  * timestamps are host ``perf_counter_ns`` taken ONLY where the engine
    already syncs or dispatches — tracing adds zero device round-trips and
    must not change ``host_syncs_per_token``;
  * recording one span is two clock reads and one list append — no
    serialization until ``save()``;
  * a disabled tracer (``enabled=False``) short-circuits to a no-op
    context manager, so engine call sites need no conditionals.

When ``annotate_xla=True`` (default) every synchronous span also enters a
``jax.profiler.TraceAnnotation`` with the same name, so host spans line up
with XLA device traces when a ``jax.profiler.trace()`` session is active.
The import is lazy and failure-tolerant: the tracer works in environments
where jax (or its profiler) is absent.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

__all__ = ["Tracer", "validate_chrome_trace", "load_trace"]


def _trace_annotation_cls():
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:  # jax absent or profiler API moved
        return None


class Tracer:
    """Append-only span/event recorder emitting Chrome-trace JSON.

    Event kinds used (Chrome Trace Event Format):
      * ``X`` complete spans (``span()`` context manager / ``complete()``
        for intervals the caller already timed),
      * ``i`` instants (``instant()``),
      * ``b``/``e`` async spans (``async_begin``/``async_end``) for request
        lifetimes that interleave across chunk boundaries.

    Nesting is tracked per thread; ``span()`` enforces stack discipline by
    construction (context manager), which is exactly the invariant Perfetto
    requires of same-track complete events.
    """

    def __init__(self, *, enabled: bool = True, annotate_xla: bool = True,
                 process_name: str = "repro-serve", pid: Optional[int] = None):
        self.enabled = enabled
        self.process_name = process_name
        self.pid = os.getpid() if pid is None else int(pid)
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._t0 = time.perf_counter_ns()
        self._ann_cls = _trace_annotation_cls() if annotate_xla else None
        if enabled:
            self._meta("process_name", {"name": process_name})

    # -- clock ------------------------------------------------------------
    def now_ns(self) -> int:
        return time.perf_counter_ns()

    def _us(self, t_ns: int) -> float:
        return (t_ns - self._t0) / 1e3

    def _tid(self) -> int:
        tid = getattr(self._tls, "tid", None)
        if tid is None:
            tid = threading.get_ident() & 0x7FFFFFFF
            self._tls.tid = tid
            self._meta("thread_name",
                       {"name": threading.current_thread().name}, tid=tid)
        return tid

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    # -- recording --------------------------------------------------------
    def _meta(self, name: str, args: dict, tid: int = 0):
        with self._lock:
            self._events.append({"name": name, "ph": "M", "pid": self.pid,
                                 "tid": tid, "ts": 0, "args": args})

    def _emit(self, ev: Dict[str, Any]):
        with self._lock:
            self._events.append(ev)

    @contextmanager
    def span(self, name: str, cat: str = "engine", **attrs):
        """Synchronous complete span; nests per thread (stack discipline)."""
        if not self.enabled:
            yield
            return
        ann = self._ann_cls(name) if self._ann_cls is not None else None
        if ann is not None:
            ann.__enter__()
        self._tls.depth = self._depth() + 1
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            t1 = time.perf_counter_ns()
            self._tls.depth = self._depth() - 1
            if ann is not None:
                ann.__exit__(None, None, None)
            self._emit({"name": name, "ph": "X", "cat": cat,
                        "pid": self.pid, "tid": self._tid(),
                        "ts": self._us(t0), "dur": (t1 - t0) / 1e3,
                        "args": attrs})

    def complete(self, name: str, t0_ns: int, t1_ns: int,
                 cat: str = "engine", **attrs):
        """Record an interval the caller already timed (both ends captured
        at existing sync points) — no extra clock reads on the hot path."""
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "X", "cat": cat, "pid": self.pid,
                    "tid": self._tid(), "ts": self._us(t0_ns),
                    "dur": max(0.0, (t1_ns - t0_ns) / 1e3), "args": attrs})

    def instant(self, name: str, cat: str = "engine", **attrs):
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "i", "s": "t", "cat": cat,
                    "pid": self.pid, "tid": self._tid(),
                    "ts": self._us(time.perf_counter_ns()), "args": attrs})

    def async_begin(self, name: str, id: int, cat: str = "request", **attrs):
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "b", "cat": cat, "id": int(id),
                    "pid": self.pid, "tid": self._tid(),
                    "ts": self._us(time.perf_counter_ns()), "args": attrs})

    def async_end(self, name: str, id: int, cat: str = "request", **attrs):
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "e", "cat": cat, "id": int(id),
                    "pid": self.pid, "tid": self._tid(),
                    "ts": self._us(time.perf_counter_ns()), "args": attrs})

    # -- export -----------------------------------------------------------
    def __len__(self):
        with self._lock:
            return len(self._events)

    def chrome_trace(self) -> dict:
        """The Chrome-trace JSON object (Perfetto's legacy-JSON loader)."""
        with self._lock:
            events = list(self._events)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"process": self.process_name}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def load_trace(path: str) -> List[dict]:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"),
                                                   list):
        raise ValueError(f"{path}: not a Chrome-trace JSON object "
                         "(want {'traceEvents': [...]})")
    return doc["traceEvents"]


def validate_chrome_trace(events: List[dict]) -> dict:
    """Validate events against the Chrome Trace Event Format.

    Checks (the subset Perfetto's legacy JSON importer enforces):
      * every event has ``name``/``ph``/``pid``/``tid``/``ts`` with sane
        types; ``ts``/``dur`` non-negative;
      * ``X`` events carry a ``dur``;
      * async ``b``/``e`` events carry an ``id`` and are balanced per
        (cat, id) with begin <= end timestamps;
      * ``X`` events on one (pid, tid) track nest properly (no partial
        overlap — the stack-discipline invariant).

    Returns summary counts; raises ``ValueError`` on the first violation.
    """
    counts: Dict[str, int] = {}
    async_open: Dict[tuple, List[float]] = {}
    by_track: Dict[tuple, List[dict]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for field, types in (("name", str), ("ph", str),
                             ("pid", int), ("tid", int),
                             ("ts", (int, float))):
            if not isinstance(ev.get(field), types):
                raise ValueError(f"event {i} ({ev.get('name')!r}): field "
                                 f"{field!r} missing or mistyped: {ev}")
        ph = ev["ph"]
        counts[ph] = counts.get(ph, 0) + 1
        if ev["ts"] < 0:
            raise ValueError(f"event {i} ({ev['name']!r}): negative ts")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i} ({ev['name']!r}): X event "
                                 f"needs non-negative dur, got {ev.get('dur')!r}")
            by_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
        elif ph in ("b", "e"):
            if "id" not in ev:
                raise ValueError(f"event {i} ({ev['name']!r}): async "
                                 f"{ph!r} event needs an id")
            key = (ev.get("cat", ""), ev["id"])
            if ph == "b":
                async_open.setdefault(key, []).append(ev["ts"])
            else:
                opens = async_open.get(key)
                if not opens:
                    raise ValueError(f"event {i} ({ev['name']!r}): async end "
                                     f"without begin for id={ev['id']}")
                t_b = opens.pop()
                if ev["ts"] < t_b:
                    raise ValueError(f"event {i} ({ev['name']!r}): async end "
                                     f"ts {ev['ts']} precedes begin {t_b}")
    dangling = {k: v for k, v in async_open.items() if v}
    if dangling:
        raise ValueError(f"unbalanced async spans (begin without end): "
                         f"{sorted(dangling)[:5]}")
    # same-track X events must nest (never partially overlap); tolerance is
    # 1e-3 us (1 ns): abutting spans share a boundary timestamp whose us
    # conversion rounds differently for end-of-previous vs start-of-next
    for (pid, tid), evs in by_track.items():
        evs = sorted(evs, key=lambda e: (e["ts"], -e["dur"]))
        stack: List[tuple] = []  # (end_ts, name)
        for ev in evs:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and stack[-1][0] <= t0 + 1e-3:
                stack.pop()
            if stack and t1 > stack[-1][0] + 1e-3:
                raise ValueError(
                    f"span {ev['name']!r} [{t0:.1f}, {t1:.1f}]us on track "
                    f"({pid}, {tid}) partially overlaps enclosing "
                    f"{stack[-1][1]!r} (ends {stack[-1][0]:.1f}us): "
                    "X events on one track must nest")
            stack.append((t1, ev["name"]))
    return {"events": len(events), "by_phase": counts,
            "tracks": len(by_track)}
