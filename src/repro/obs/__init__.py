"""Unified telemetry layer: metrics registry, request-lifecycle tracing,
and kernel-dispatch profiling.

Three pillars, all host-side and dependency-free (no jax import at module
scope, so the kernels/core layers can hook in without cycles):

  * :mod:`repro.obs.metrics` — typed counters / gauges / bounded-reservoir
    histograms behind a :class:`MetricsRegistry`, with JSON snapshot and
    Prometheus text exposition. The serving engine, block pool, tuning
    cache, and benches all emit through it.
  * :mod:`repro.obs.trace` — span/event tracer exporting Chrome-trace /
    Perfetto JSON. Spans optionally wrap ``jax.profiler.TraceAnnotation``
    so host spans line up with XLA device profiles. The overhead contract:
    timestamps are taken only at host sync points that already exist —
    tracing never adds a device round-trip.
  * :mod:`repro.obs.dispatch` — trace-time kernel-dispatch recorder:
    which (shape-key, fusion, blocks) actually dispatched, tuned vs
    heuristic, per jitted-program trace.

See docs/OBSERVABILITY.md for the span taxonomy, metric names/units, and
the overhead contract gated by ``benchmarks/bench_telemetry.py``.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               export_stats)
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.obs import dispatch

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "export_stats", "Tracer", "validate_chrome_trace", "dispatch"]
