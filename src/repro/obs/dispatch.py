"""Kernel-dispatch profiling: which mpGEMM config actually executed.

``kernels.ops.resolve_dispatch`` is the single trace-time decision point
for every Pallas mpGEMM a jitted program contains; ``core.lmma.
select_fusion`` is the VMEM-fit heuristic under it. Both call ``record()``
here — a no-op unless a :class:`DispatchRecorder` is active — so a serve
run can dump exactly which (shape-key, fusion, blocks) dispatched, whether
the decision came from the measured tuning cache or the heuristic, per
traced program.

This mirrors the per-kernel visibility T-MAC / LUT-GEMM use for their
mpGEMM breakdown tables: aggregate tok/s can hide one projection silently
falling back to the staged path; the dispatch log cannot.

The hooks run at TRACE time (host python, once per compiled program), never
inside compiled code — recording costs nothing per decode step. The module
is dependency-free so the kernels/core layers can import it without cycles.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["DispatchRecord", "DispatchRecorder", "enable", "disable",
           "get_active", "record", "recording"]


@dataclasses.dataclass
class DispatchRecord:
    """One deduplicated dispatch decision (+ how often it was traced)."""

    kind: str            # "dispatch" (resolve_dispatch) | "select_fusion"
    key: str             # autotune.shape_key / lmma descriptor name
    fusion: str          # resolved fusion actually dispatched
    requested: str       # caller policy: auto | tuned | fused | staged
    source: str          # "tuned" (cache hit) | "heuristic" | "forced"
    block_m: int = 0
    block_n: int = 0
    block_g: int = 0
    count: int = 1       # times this exact decision was traced

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class DispatchRecorder:
    def __init__(self):
        self._lock = threading.Lock()
        self._records: Dict[Tuple, DispatchRecord] = {}

    def record(self, kind: str, key: str, fusion: str, requested: str,
               source: str, blocks: Tuple[int, int, int] = (0, 0, 0)):
        k = (kind, key, fusion, requested, source, tuple(blocks))
        with self._lock:
            rec = self._records.get(k)
            if rec is None:
                self._records[k] = DispatchRecord(
                    kind, key, fusion, requested, source,
                    blocks[0], blocks[1], blocks[2])
            else:
                rec.count += 1

    def records(self, kind: Optional[str] = None) -> List[DispatchRecord]:
        with self._lock:
            recs = list(self._records.values())
        if kind is not None:
            recs = [r for r in recs if r.kind == kind]
        return sorted(recs, key=lambda r: (r.kind, r.key, r.fusion))

    def summary(self) -> dict:
        """Aggregate for stats()/bench JSON: decisions by source + the full
        per-shape table."""
        recs = self.records()
        disp = [r for r in recs if r.kind == "dispatch"]
        return {
            "decisions": len(disp),
            "tuned": sum(1 for r in disp if r.source == "tuned"),
            "heuristic": sum(1 for r in disp if r.source == "heuristic"),
            "forced": sum(1 for r in disp if r.source == "forced"),
            "records": [r.as_dict() for r in recs],
        }

    def clear(self):
        with self._lock:
            self._records.clear()

    def __len__(self):
        with self._lock:
            return len(self._records)


_ACTIVE: Optional[DispatchRecorder] = None
_GUARD = threading.Lock()


def enable(recorder: Optional[DispatchRecorder] = None) -> DispatchRecorder:
    """Install (and return) the active recorder; idempotent if one is
    already active and none is supplied."""
    global _ACTIVE
    with _GUARD:
        if recorder is not None:
            _ACTIVE = recorder
        elif _ACTIVE is None:
            _ACTIVE = DispatchRecorder()
        return _ACTIVE


def disable():
    global _ACTIVE
    with _GUARD:
        _ACTIVE = None


def get_active() -> Optional[DispatchRecorder]:
    return _ACTIVE


def record(kind: str, key: str, fusion: str, requested: str, source: str,
           blocks: Tuple[int, int, int] = (0, 0, 0)):
    """Module-level hook for ops/lmma: single ``is None`` check when
    profiling is off."""
    rec = _ACTIVE
    if rec is not None:
        rec.record(kind, key, fusion, requested, source, blocks)


class recording:
    """Context manager: install a fresh recorder, restore the prior one."""

    def __enter__(self) -> DispatchRecorder:
        self._prev = get_active()
        self._rec = DispatchRecorder()
        enable(self._rec)
        return self._rec

    def __exit__(self, *exc):
        global _ACTIVE
        with _GUARD:
            _ACTIVE = self._prev
        return False
