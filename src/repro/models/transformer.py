"""Decoder-only dense transformer (llama/qwen family) with scan-over-layers.

The stack is the template for every LM family here: embedding → L × block
(lax.scan over stacked params, jax.checkpoint'd body) → final norm → LM head.
Blocks differ per family (dense MLP / MoE / mamba / hybrid); this module
provides the dense one plus the shared embed/head/loss machinery.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_tree, shard
from repro.models import kvcache, layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# shared embed / head / loss
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_apply(p: Params, tokens: jax.Array) -> jax.Array:
    h = jnp.take(p["table"], tokens, axis=0)
    return shard(h, "batch", "seq", None)


def head_apply(p: Params, h: jax.Array, quant=None) -> jax.Array:
    logits = L.lut_dense(p, h, quant)
    return shard(logits, "batch", None, "model")  # vocab-sharded logits


def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy over (possibly vocab-sharded) logits."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# dense block
# ---------------------------------------------------------------------------

def block_init(key, cfg, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg, dtype=dtype),
        "mlp_norm": L.norm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def block_apply(p: Params, h: jax.Array, cfg, *, cache=None, cache_pos=0,
                window=None, quant=None, page_table=None):
    a, cache = L.attention_apply(
        p["attn"], L.rms_norm(p["attn_norm"], h, cfg.norm_eps), cfg,
        kv_cache=cache, cache_pos=cache_pos, window=window, quant=quant,
        page_table=page_table)
    h = shard(h + a, "batch", "seq", None)
    m = L.mlp_apply(p["mlp"], L.rms_norm(p["mlp_norm"], h, cfg.norm_eps), quant)
    return shard(h + m, "batch", "seq", None), cache


# ---------------------------------------------------------------------------
# stacked layers: init via vmap, apply via scanned+remat'd body
# ---------------------------------------------------------------------------

def stack_init(key, cfg, n_layers: int, block_init_fn=block_init,
               dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: block_init_fn(k, cfg, dtype))(keys)


def stack_apply(stacked: Params, h: jax.Array, cfg, *,
                caches=None, cache_pos=0, window=None, quant=None,
                block_apply_fn=block_apply, page_table=None):
    """lax.scan over the L leading axis of params (+ caches).

    ``page_table`` is closed over, NOT scanned: it has no leading L dim
    (every layer's pool blocks share one per-slot table)."""

    def body(carry, xs):
        hh = carry
        if caches is None:
            lp = constrain_tree(xs)  # §Perf T1: pin layer-slice shardings
            hh, _ = block_apply_fn(lp, hh, cfg, cache=None, cache_pos=cache_pos,
                                   window=window, quant=quant)
            return hh, None
        lp, lc = xs
        lp = constrain_tree(lp)
        hh, nc = block_apply_fn(lp, hh, cfg, cache=lc, cache_pos=cache_pos,
                                window=window, quant=quant,
                                page_table=page_table)
        return hh, nc

    body = jax.checkpoint(body, prevent_cse=False)
    xs = stacked if caches is None else (stacked, caches)
    h, new_caches = jax.lax.scan(body, h, xs)
    return h, new_caches


# ---------------------------------------------------------------------------
# dense LM
# ---------------------------------------------------------------------------

def init(key, cfg, dtype=None) -> Params:
    dtype = dtype or cfg.param_dtype
    k_e, k_l, k_h = jax.random.split(key, 3)
    return {
        "embed": embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
        "layers": stack_init(k_l, cfg, cfg.n_layers, dtype=dtype),
        "final_norm": L.norm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def forward(params: Params, batch: Dict[str, jax.Array], cfg, *,
            caches=None, cache_pos=0, window=None,
            token_valid=None, page_table=None) -> Tuple[jax.Array, Any, Dict]:
    # token_valid ([B] real-token counts for right-padded chunked prefill) is
    # accepted for interface uniformity but unused: causal attention already
    # prevents real positions from seeing padded tails, and pad k/v land at
    # cache positions >= the slot's valid length, which every later read
    # masks via kv_valid_len (and decode overwrites them in place).
    del token_valid
    tokens = batch["tokens"]
    h = embed_apply(params["embed"], tokens).astype(cfg.activation_dtype)
    h, new_caches = stack_apply(params["layers"], h, cfg, caches=caches,
                                cache_pos=cache_pos, window=window,
                                quant=cfg.quant, page_table=page_table)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = head_apply(params["lm_head"], h, cfg.quant)
    return logits, new_caches, {}


def init_cache(cfg, batch: int, s_cache: int, window=None, dtype=jnp.bfloat16):
    return kvcache.attn_cache(cfg.n_layers, batch, s_cache, cfg.n_kv_heads,
                              cfg.head_dim, dtype, window)
