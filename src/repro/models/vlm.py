"""Llama-3.2-Vision-style VLM backbone: self-attn decoder with gated
cross-attention layers every ``xattn_every`` layers.

The vision frontend is a STUB per the assignment: ``batch["image_embeds"]``
supplies precomputed patch embeddings [B, n_img, d_model] (input_specs()
provides the ShapeDtypeStruct).  Cross-attn KV is computed once per image
and cached for decode (stacked [n_xlayers, ...]).

Structure: n_groups groups of (xattn_every-1 self layers + 1 cross layer).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_tree, shard
from repro.models import kvcache, layers as L
from repro.models import transformer as TR

Params = Dict[str, Any]


def _xattn_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_init(cfg.d_model, dtype),
        "xattn": L.attention_init(k1, cfg, cross=True, dtype=dtype),
        "gate_attn": jnp.zeros((), dtype),
        "mlp_norm": L.norm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
        "gate_mlp": jnp.zeros((), dtype),
    }


def init(key, cfg, dtype=None) -> Params:
    dtype = dtype or cfg.param_dtype
    k_e, k_s, k_x, k_h = jax.random.split(key, 4)
    n_groups = cfg.n_layers // cfg.xattn_every
    n_self = cfg.xattn_every - 1
    skeys = jax.random.split(k_s, n_groups * n_self).reshape(n_groups, n_self)
    xkeys = jax.random.split(k_x, n_groups)
    return {
        "embed": TR.embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
        "self_groups": jax.vmap(jax.vmap(
            lambda k: TR.block_init(k, cfg, dtype)))(skeys),
        "xattn_layers": jax.vmap(
            lambda k: _xattn_layer_init(k, cfg, dtype))(xkeys),
        "final_norm": L.norm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def _xattn_apply(p, h, cfg, xkv, quant):
    a, _ = L.attention_apply(
        p["xattn"], L.rms_norm(p["attn_norm"], h, cfg.norm_eps), cfg,
        xattn_kv=xkv, causal=False, use_rope=False, quant=quant)
    h = h + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(h.dtype) * a
    m = L.mlp_apply(p["mlp"], L.rms_norm(p["mlp_norm"], h, cfg.norm_eps), quant)
    h = h + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(h.dtype) * m
    return shard(h, "batch", "seq", None)


def compute_image_kv(params: Params, image_embeds: jax.Array, cfg):
    """Precompute per-cross-layer image KV [n_groups, B, n_img, KV, hd]."""
    b, n_img, _ = image_embeds.shape

    def one(xp):
        k = L.lut_dense(xp["xattn"]["wk"], image_embeds, cfg.quant)
        v = L.lut_dense(xp["xattn"]["wv"], image_embeds, cfg.quant)
        return (k.reshape(b, n_img, cfg.n_kv_heads, cfg.head_dim),
                v.reshape(b, n_img, cfg.n_kv_heads, cfg.head_dim))

    return jax.lax.map(one, params["xattn_layers"])


def forward(params: Params, batch, cfg, *, caches=None, cache_pos=0,
            window=None, token_valid=None,
            page_table=None) -> Tuple[jax.Array, Any, Dict]:
    del token_valid  # attention-only stack: see transformer.forward
    tokens = batch["tokens"]
    quant = cfg.quant
    h = TR.embed_apply(params["embed"], tokens).astype(cfg.activation_dtype)

    if "image_embeds" in batch:  # prefill/train: embed the image
        image_kv = compute_image_kv(params, batch["image_embeds"]
                                    .astype(cfg.activation_dtype), cfg)
    else:  # decode: reuse the cached image KV
        image_kv = caches["image_kv"]
    self_caches = None if caches is None else caches["kv"]

    def group_body(carry, xs):
        hh = carry
        if self_caches is None:
            gp, xp, (ik, iv) = xs
            gcache = None
        else:
            gp, xp, (ik, iv), gcache = xs

        def inner(c, lxs):
            lp = lxs if gcache is None else lxs[0]
            lp = constrain_tree(lp)  # §Perf T1
            lc = None if gcache is None else lxs[1]
            return TR.block_apply(lp, c, cfg, cache=lc, cache_pos=cache_pos,
                                  window=window, quant=quant,
                                  page_table=page_table)

        inner = jax.checkpoint(inner, prevent_cse=False)
        ixs = gp if gcache is None else (gp, gcache)
        hh, new_c = jax.lax.scan(inner, hh, ixs)
        hh = _xattn_apply(xp, hh, cfg, (ik.astype(hh.dtype), iv.astype(hh.dtype)),
                          quant)
        return hh, new_c

    group_body = jax.checkpoint(group_body, prevent_cse=False)
    xs = ((params["self_groups"], params["xattn_layers"], image_kv)
          if self_caches is None
          else (params["self_groups"], params["xattn_layers"], image_kv,
                self_caches))
    h, new_self = jax.lax.scan(group_body, h, xs)

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = TR.head_apply(params["lm_head"], h, quant)
    new_caches = None
    if caches is not None:
        new_caches = {"kv": new_self, "image_kv": image_kv}
    return logits, new_caches, {}


def init_cache(cfg, batch: int, s_cache: int, window=None, dtype=jnp.bfloat16,
               image_kv=None):
    n_groups = cfg.n_layers // cfg.xattn_every
    n_self = cfg.xattn_every - 1
    k, v = kvcache.attn_cache(n_groups * n_self, batch, s_cache,
                              cfg.n_kv_heads, cfg.head_dim, dtype, window)
    shp = (n_groups, n_self) + k.shape[1:]
    caches = {"kv": (k.reshape(shp), v.reshape(shp))}
    if image_kv is None:
        ikv = jnp.zeros((n_groups, batch, cfg.n_image_tokens,
                         cfg.n_kv_heads, cfg.head_dim), dtype)
        image_kv = (ikv, ikv)
    caches["image_kv"] = image_kv
    return caches
