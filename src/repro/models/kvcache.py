"""KV / SSM state caches.

Caches are plain pytrees stacked over layers (leading L dim) so the decode
step scans over (layer_params, layer_cache) together.

  * attention: (k, v) each [L, B, S_cache, KV, hd]; ``S_cache`` is the max
    sequence length, or the window size for rolling sliding-window caches
    (the sub-quadratic long-context decode path, long_500k).
  * mamba: {"conv": [L, B, d_conv-1, d_inner], "ssm": [L, B, ...state]}.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attn_cache", "mamba_cache", "mamba2_cache", "cache_len",
           "batch_axes", "seq_axes", "slice_batch", "merge_batch",
           "paged_gather", "paged_scatter"]


def attn_cache(n_layers: int, batch: int, s_cache: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16, window: Optional[int] = None):
    """dtype may be a jnp dtype or the string "int8" — the int8 variant
    (KV-cache quantization, paper §5) returns (k, v, k_scale, v_scale) with
    per-(position, head) absmax scales; attention dequantizes per chunk."""
    s = min(s_cache, window) if window else s_cache
    shape = (n_layers, batch, s, n_kv, head_dim)
    if dtype == "int8" or dtype == jnp.int8:
        sshape = (n_layers, batch, s, n_kv, 1)
        return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.ones(sshape, jnp.float32), jnp.ones(sshape, jnp.float32))
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def mamba_cache(n_layers: int, batch: int, d_inner: int, d_state: int,
                d_conv: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((n_layers, batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((n_layers, batch, d_inner, d_state), dtype),
    }


def mamba2_cache(n_layers: int, batch: int, n_heads: int, head_dim: int,
                 d_state: int, d_inner: int, d_conv: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((n_layers, batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((n_layers, batch, n_heads, head_dim, d_state), dtype),
    }


def cache_len(cache) -> int:
    """Sequence capacity of an attention cache."""
    return cache[0].shape[2]


# ---------------------------------------------------------------------------
# per-slot views (continuous-batching engine)
# ---------------------------------------------------------------------------
# The batch dim is NOT a fixed axis across cache layouts: plain stacks carry
# it at axis 1 ([L, B, ...]) but e.g. the zamba2 hybrid stacks its mamba
# leaves [n_groups, attn_every, B, ...]. ``batch_axes`` discovers the axis
# per leaf by diffing the shapes of two differently-batched cache structs
# (cheap: eval_shape only), and slice/merge then give the serving engine an
# O(slot)-sized view of one slot's state for chunked prefill.

def batch_axes(cache_b1, cache_b2):
    """Per-leaf batch axis, from two cache structs built with batch=1/2."""
    def one(path, a, b):
        diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape))
                 if p != q]
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous batch axis for cache leaf at "
                f"{jax.tree_util.keystr(path)!r}: probe shapes {a.shape} vs "
                f"{b.shape} differ in {len(diffs)} dims (expected exactly 1)")
        return diffs[0]
    return jax.tree_util.tree_map_with_path(one, cache_b1, cache_b2)


def seq_axes(cache_s1, cache_s2):
    """Per-leaf sequence axis, from two cache structs built with different
    ``s_cache`` (same batch). Leaves whose shape is independent of the
    sequence capacity — SSM conv/recurrent state, cross-attention and image
    KV, rolling-window caches clamped below both probes — return ``-1``:
    they carry O(1) state per slot and stay dense slot-indexed under the
    block-paged pool (only sequence-extensive leaves are worth paging)."""
    def one(path, a, b):
        if a.shape == b.shape:
            return -1
        diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape))
                 if p != q]
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous sequence axis for cache leaf at "
                f"{jax.tree_util.keystr(path)!r}: probe shapes {a.shape} vs "
                f"{b.shape} differ in {len(diffs)} dims (expected 0 or 1)")
        return diffs[0]
    return jax.tree_util.tree_map_with_path(one, cache_s1, cache_s2)


def slice_batch(caches, axes, idx):
    """Extract slot ``idx`` as a batch-1 cache pytree (dynamic, jit-safe)."""
    return jax.tree.map(
        lambda c, ax: jax.lax.dynamic_slice_in_dim(c, idx, 1, axis=ax),
        caches, axes)


def merge_batch(caches, slot_caches, axes, idx):
    """Write a batch-1 cache pytree back into slot ``idx`` of the pool."""
    return jax.tree.map(
        lambda c, sc, ax: jax.lax.dynamic_update_slice_in_dim(
            c, sc.astype(c.dtype), idx, axis=ax),
        caches, slot_caches, axes)


# ---------------------------------------------------------------------------
# block-paged pool views (paged KV cache, vLLM/TensorRT-LLM style)
# ---------------------------------------------------------------------------
# A paged attention cache stores fixed-size blocks in a shared pool: the
# per-layer leaf is [num_blocks, block_size, ...] instead of [B, S, ...],
# and each slot's logical sequence is the concatenation of the blocks its
# page-table row names. Logical position p of slot i lives at
# pool[table[i, p // bs], p % bs]. Block 0 is reserved as the NULL block:
# slots with no allocation (idle / retired) point every table entry at it,
# so their masked-out decode writes land somewhere harmless. Reads mask by
# valid length, and the flash-softmax turns masked scores into exactly-zero
# probabilities (finfo.min -> exp underflow), so garbage beyond the valid
# length — null-block junk included — contributes exactly 0.0 and the paged
# path is bit-identical to the dense one.

def paged_gather(leaf, page_table):
    """[N, bs, ...] pool leaf + [B, nb] page table -> [B, nb*bs, ...]
    contiguous logical view (block j of a slot lands at view offset j*bs)."""
    g = leaf[page_table]
    b, nb, bs = g.shape[:3]
    return g.reshape((b, nb * bs) + g.shape[3:])


def paged_scatter(leaf, vals, page_table, positions):
    """Write ``vals`` [B, S, ...] at logical ``positions`` [B, S] of each
    slot's paged sequence; ``leaf`` is a [N, bs, ...] pool leaf.

    Positions at or beyond the table's reach (nb*bs) are routed to the null
    block instead of letting the gather clamp silently alias a real block
    (a right-padded prefill tail can run past the allocated range)."""
    bs = leaf.shape[1]
    nb = page_table.shape[1]
    blk = positions // bs
    phys = jnp.take_along_axis(page_table, jnp.minimum(blk, nb - 1), axis=1)
    phys = jnp.where(blk < nb, phys, 0)
    return leaf.at[phys, positions % bs].set(vals.astype(leaf.dtype))
