"""KV / SSM state caches.

Caches are plain pytrees stacked over layers (leading L dim) so the decode
step scans over (layer_params, layer_cache) together.

  * attention: (k, v) each [L, B, S_cache, KV, hd]; ``S_cache`` is the max
    sequence length, or the window size for rolling sliding-window caches
    (the sub-quadratic long-context decode path, long_500k).
  * mamba: {"conv": [L, B, d_conv-1, d_inner], "ssm": [L, B, ...state]}.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["attn_cache", "mamba_cache", "mamba2_cache", "cache_len"]


def attn_cache(n_layers: int, batch: int, s_cache: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16, window: Optional[int] = None):
    """dtype may be a jnp dtype or the string "int8" — the int8 variant
    (KV-cache quantization, paper §5) returns (k, v, k_scale, v_scale) with
    per-(position, head) absmax scales; attention dequantizes per chunk."""
    s = min(s_cache, window) if window else s_cache
    shape = (n_layers, batch, s, n_kv, head_dim)
    if dtype == "int8" or dtype == jnp.int8:
        sshape = (n_layers, batch, s, n_kv, 1)
        return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.ones(sshape, jnp.float32), jnp.ones(sshape, jnp.float32))
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def mamba_cache(n_layers: int, batch: int, d_inner: int, d_state: int,
                d_conv: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((n_layers, batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((n_layers, batch, d_inner, d_state), dtype),
    }


def mamba2_cache(n_layers: int, batch: int, n_heads: int, head_dim: int,
                 d_state: int, d_inner: int, d_conv: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((n_layers, batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((n_layers, batch, n_heads, head_dim, d_state), dtype),
    }


def cache_len(cache) -> int:
    """Sequence capacity of an attention cache."""
    return cache[0].shape[2]
