"""KV / SSM state caches.

Caches are plain pytrees stacked over layers (leading L dim) so the decode
step scans over (layer_params, layer_cache) together.

  * attention: (k, v) each [L, B, S_cache, KV, hd]; ``S_cache`` is the max
    sequence length, or the window size for rolling sliding-window caches
    (the sub-quadratic long-context decode path, long_500k).
  * mamba: {"conv": [L, B, d_conv-1, d_inner], "ssm": [L, B, ...state]}.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attn_cache", "mamba_cache", "mamba2_cache", "cache_len",
           "batch_axes", "slice_batch", "merge_batch"]


def attn_cache(n_layers: int, batch: int, s_cache: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16, window: Optional[int] = None):
    """dtype may be a jnp dtype or the string "int8" — the int8 variant
    (KV-cache quantization, paper §5) returns (k, v, k_scale, v_scale) with
    per-(position, head) absmax scales; attention dequantizes per chunk."""
    s = min(s_cache, window) if window else s_cache
    shape = (n_layers, batch, s, n_kv, head_dim)
    if dtype == "int8" or dtype == jnp.int8:
        sshape = (n_layers, batch, s, n_kv, 1)
        return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.ones(sshape, jnp.float32), jnp.ones(sshape, jnp.float32))
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def mamba_cache(n_layers: int, batch: int, d_inner: int, d_state: int,
                d_conv: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((n_layers, batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((n_layers, batch, d_inner, d_state), dtype),
    }


def mamba2_cache(n_layers: int, batch: int, n_heads: int, head_dim: int,
                 d_state: int, d_inner: int, d_conv: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((n_layers, batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((n_layers, batch, n_heads, head_dim, d_state), dtype),
    }


def cache_len(cache) -> int:
    """Sequence capacity of an attention cache."""
    return cache[0].shape[2]


# ---------------------------------------------------------------------------
# per-slot views (continuous-batching engine)
# ---------------------------------------------------------------------------
# The batch dim is NOT a fixed axis across cache layouts: plain stacks carry
# it at axis 1 ([L, B, ...]) but e.g. the zamba2 hybrid stacks its mamba
# leaves [n_groups, attn_every, B, ...]. ``batch_axes`` discovers the axis
# per leaf by diffing the shapes of two differently-batched cache structs
# (cheap: eval_shape only), and slice/merge then give the serving engine an
# O(slot)-sized view of one slot's state for chunked prefill.

def batch_axes(cache_b1, cache_b2):
    """Per-leaf batch axis, from two cache structs built with batch=1/2."""
    def one(a, b):
        diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape))
                 if p != q]
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous batch axis for cache leaf {a.shape} vs {b.shape}")
        return diffs[0]
    return jax.tree.map(one, cache_b1, cache_b2)


def slice_batch(caches, axes, idx):
    """Extract slot ``idx`` as a batch-1 cache pytree (dynamic, jit-safe)."""
    return jax.tree.map(
        lambda c, ax: jax.lax.dynamic_slice_in_dim(c, idx, 1, axis=ax),
        caches, axes)


def merge_batch(caches, slot_caches, axes, idx):
    """Write a batch-1 cache pytree back into slot ``idx`` of the pool."""
    return jax.tree.map(
        lambda c, sc, ax: jax.lax.dynamic_update_slice_in_dim(
            c, sc.astype(c.dtype), idx, axis=ax),
        caches, slot_caches, axes)
