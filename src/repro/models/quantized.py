"""Convert float param trees to packed low-bit serving trees.

Walks the params pytree; every quantizable projection ``{"w": [in,out]}``
becomes ``{"qw": QuantizedWeight}`` (bias kept), and stacked MoE expert
weights [E, d_in, d_out] become batched QuantizedWeights (vmapped quantize).

Never quantized (DESIGN.md §5): embedding table, MoE router, norms, gates,
conv taps, SSM A/D/dt vectors, positional tables.
"""

from __future__ import annotations

import re
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import quantize as Q

# parent dict names whose "w" is a quantizable projection
_QUANTIZABLE = re.compile(
    r"(wq|wk|wv|wo|gate|up|down|in_proj|out_proj|x_proj|dt_proj|lm_head)$")
_NEVER = re.compile(r"(router|embed|pos_embed)")


def _quantize_2d(w, quant) -> Q.QuantizedWeight:
    qw = Q.quantize(w.T, quant.get("weight_bits", 2),
                    k_group=quant.get("k_group", 4),
                    scheme=quant.get("scheme", "symmetric"))
    if quant.get("store") == "cw":
        qw = Q.to_cw_format(qw)
    return qw


def quantize_params(params: Dict[str, Any], quant: dict) -> Dict[str, Any]:
    """Returns a new tree with projections replaced by packed weights.

    Validates the serving-path dispatch keys here, at conversion time, so a
    bad ``mpgemm_mode``/``fusion`` fails before the first jitted forward.
    """
    from repro.core.mpgemm import FUSION_MODES, MPGEMM_MODES
    mode = quant.get("mpgemm_mode", "lut_xla")
    if mode not in MPGEMM_MODES:
        raise ValueError(f"mpgemm_mode {mode!r} not in {MPGEMM_MODES}")
    fusion = quant.get("fusion", "auto")
    if fusion not in FUSION_MODES:
        raise ValueError(f"fusion {fusion!r} not in {FUSION_MODES}")
    kg = quant.get("k_group", 4)

    def walk(node, path):
        if isinstance(node, dict):
            if "w" in node and _QUANTIZABLE.search(path) and not _NEVER.search(path):
                w = node["w"]
                if w.ndim == 2 and w.shape[0] % kg == 0:
                    out = {"qw": _quantize_2d(w, quant)}
                    if "b" in node:
                        out["b"] = node["b"]
                    return out
            if path.endswith("experts"):
                # stacked expert weights [E, d_in, d_out] -> batched QW
                out = {}
                for name, w in node.items():
                    if w.ndim == 3 and w.shape[1] % kg == 0:
                        out[name + "_qw"] = jax.vmap(
                            lambda we: _quantize_2d(we, quant))(w)
                    else:
                        out[name] = w
                return out
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return node

    return walk(params, "")


def quantized_bytes(params) -> int:
    """Total HBM bytes of a (possibly quantized) param tree."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))
