"""Convert float param trees to packed low-bit serving trees.

Walks the params pytree; every quantizable projection ``{"w": [in,out]}``
becomes ``{"qw": QuantizedWeight}`` (bias kept), and stacked MoE expert
weights [E, d_in, d_out] become batched QuantizedWeights (vmapped quantize).

Never quantized (DESIGN.md §5): embedding table, MoE router, norms, gates,
conv taps, SSM A/D/dt vectors, positional tables.

Per-arch mixed precision: ``quant["skip"]`` is a path regex for
projections that must stay float — the standard sensitive-module escape
hatch (AWQ/GPTQ-style skip lists). Quantization error injected into SSM
dynamics compounds through the recurrence (and, for zamba2, through the
reused shared blocks), so hybrid configs keep their mamba in/out
projections in fp while still packing attention, MLP, and the LM head.
"""

from __future__ import annotations

import re
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import quantize as Q

# parent dict names whose "w" is a quantizable projection
_QUANTIZABLE = re.compile(
    r"(wq|wk|wv|wo|gate|up|down|in_proj|out_proj|x_proj|dt_proj|lm_head)$")
_NEVER = re.compile(r"(router|embed|pos_embed)")


def _quantize_2d(w, quant) -> Q.QuantizedWeight:
    qw = Q.quantize(w.T, quant.get("weight_bits", 2),
                    k_group=quant.get("k_group", 4),
                    scheme=quant.get("scheme", "symmetric"))
    if quant.get("store") == "cw":
        qw = Q.to_cw_format(qw)
    return qw


def _quantize_stacked(w, quant) -> Q.QuantizedWeight:
    """[..., d_in, d_out] with any leading stacked dims (layer stacks,
    zamba2 groups, stacked MoE experts) -> batched QuantizedWeight whose
    children carry the same leading dims (packed [..., N, bytes]).

    ``lax.scan`` over a layer stack slices each pytree child's leading dim
    and rebuilds the per-layer QuantizedWeight via tree_unflatten, so the
    scanned forwards consume these with no special casing.
    """
    fn = lambda we: _quantize_2d(we, quant)
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w)


def quantize_params(params: Dict[str, Any], quant: dict) -> Dict[str, Any]:
    """Returns a new tree with projections replaced by packed weights.

    Validates the serving-path dispatch keys here, at conversion time, so a
    bad ``mpgemm_mode``/``fusion`` fails before the first jitted forward.
    """
    from repro.core.mpgemm import FUSION_MODES, MPGEMM_MODES
    mode = quant.get("mpgemm_mode", "lut_xla")
    if mode not in MPGEMM_MODES:
        raise ValueError(f"mpgemm_mode {mode!r} not in {MPGEMM_MODES}")
    fusion = quant.get("fusion", "auto")
    if fusion not in FUSION_MODES:
        raise ValueError(f"fusion {fusion!r} not in {FUSION_MODES}")
    if mode == "fp16":
        # fp16 is the float reference path: packing here would force a
        # per-step dequantize inside the layer scan for zero memory win
        return params
    kg = quant.get("k_group", 4)
    skip = re.compile(quant["skip"]) if quant.get("skip") else None

    def walk(node, path):
        if isinstance(node, dict):
            if skip is not None and skip.search(path):
                return node
            if "w" in node and _QUANTIZABLE.search(path) and not _NEVER.search(path):
                w = node["w"]
                # any number of leading stacked dims: per-layer stacks
                # [L, in, out], zamba2 group stacks [G, P, in, out], ...
                if w.ndim >= 2 and w.shape[-2] % kg == 0:
                    out = {"qw": _quantize_stacked(w, quant)}
                    if "b" in node:
                        out["b"] = node["b"]
                    return out
            if path.endswith("experts"):
                # stacked expert weights [(L,) E, d_in, d_out] -> batched QW
                out = {}
                for name, w in node.items():
                    if w.ndim >= 3 and w.shape[-2] % kg == 0:
                        out[name + "_qw"] = _quantize_stacked(w, quant)
                    else:
                        out[name] = w
                return out
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return node

    return walk(params, "")


def to_cw_params(params):
    """Convert every packed ``QuantizedWeight`` leaf to the offline-CW store
    (bit-exact for the lut_xla path; see ``Q.to_cw_format``).

    The LUT hardware consumes packed planes directly, but the XLA emulation
    must expand packed -> codeword matrix on every call — hoisting that
    expansion here (once, at load time) trades 4x weight bytes at W2 for
    removing the per-step unpack from the decode scan. Stacked leading dims
    (layer stacks, expert stacks) are vmapped through.
    """
    def conv(node):
        if isinstance(node, Q.QuantizedWeight) and node.packed is not None:
            fn = Q.to_cw_format
            for _ in range(node.packed.ndim - 2):
                fn = jax.vmap(fn)
            return fn(node)
        return node

    return jax.tree.map(
        conv, params,
        is_leaf=lambda n: isinstance(n, Q.QuantizedWeight))


def plane_sliced_params(params, keep_planes: int):
    """Plane-sliced *execution view* of a packed param tree (§3.1.2).

    Every packed ``QuantizedWeight`` leaf is replaced by its top-
    ``keep_planes`` view (``QuantizedWeight.plane_slice``) — the same
    buffers reinterpreted at a lower plane count, so the returned tree is a
    coarser draft model that costs ZERO extra weight HBM (self-speculative
    decoding's draft). Float leaves (norms, embeddings, skipped
    projections) are shared as-is, keeping the draft/target LM head and
    embedding identical. Raises if any quantized leaf lacks the packed
    store (CW-only trees bake all planes into the codeword matrix and
    cannot be re-sliced — pin ``quant["store"]="packed"``).
    """
    def conv(node):
        if isinstance(node, Q.QuantizedWeight):
            if node.packed is None:
                raise ValueError(
                    "plane_sliced_params: CW-store weight cannot be "
                    "plane-sliced; keep quant['store']='packed' for the "
                    "self-speculation draft view")
            return node.plane_slice(keep_planes)
        return node

    return jax.tree.map(
        conv, params,
        is_leaf=lambda n: isinstance(n, Q.QuantizedWeight))


def extra_hbm_bytes(view_params, base_params) -> int:
    """Bytes in ``view_params`` whose buffers are NOT shared (by identity)
    with ``base_params`` — the acceptance-criterion probe that the draft
    view really is zero-copy."""
    base_ids = {id(x) for x in jax.tree_util.tree_leaves(base_params)
                if hasattr(x, "size")}
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(view_params)
               if hasattr(x, "size") and id(x) not in base_ids)


def quantized_bytes(params) -> int:
    """Total HBM bytes of a (possibly quantized) param tree."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))
