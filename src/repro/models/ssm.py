"""Mamba1 / Mamba2 selective-state-space blocks (falcon-mamba, zamba2).

Training/prefill runs a *chunked* selective scan: an outer lax.scan over
sequence chunks carries the SSM state, and within a chunk the recurrence is
evaluated with jax.lax.associative_scan — no [B, S, d_inner, d_state] global
materialization, memory is O(B · chunk · state) transient + one carry per
chunk. Decode is the O(1)-state single-step update (this is why the SSM and
hybrid archs are the ones that run the long_500k shape).

The paper's technique applies to the dense projections (in/out/x/dt): they
all go through LutDense. The scan itself is activation×activation (no static
low-bit operand) — out of mpGEMM scope, see DESIGN.md §5.

Mamba1 uses the lazy chunked scan; mamba2 uses the SSD duality (§Perf C2):
intra-chunk recurrence as masked [c, c] score matmuls on the MXU, so the
[c, hd, d_state] state tensor never materializes. Simplifications vs
reference mamba (documented in DESIGN.md): conv on the x-path only,
ngroups=1.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# depthwise causal conv (shared by mamba1/2)
# ---------------------------------------------------------------------------

def _causal_dwconv(x, conv_w, conv_b, conv_state=None, valid=None):
    """x [B,S,C], conv_w [W,C] depthwise causal; returns (y, new_state).

    ``valid`` ([B] count of real tokens from the left, None = all) makes the
    carried conv state end at each row's last *real* token, so a right-padded
    tail chunk (the serving engine's fixed-shape chunked prefill) leaves the
    state exactly as if only the real tokens had been seen. Conv *outputs*
    are causal, so real positions are unaffected by the padding either way.
    """
    b, s, c = x.shape
    w = conv_w.shape[0]
    if conv_state is None:
        left = jnp.zeros((b, w - 1, c), x.dtype)
    else:
        left = conv_state.astype(x.dtype)
    xp = jnp.concatenate([left, x], axis=1)  # [B, S+W-1, C]
    y = jnp.zeros((b, s, c), jnp.float32)
    for i in range(w):  # W is tiny (4): unrolled taps beat a conv call
        y = y + xp[:, i:i + s, :].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    y = y + conv_b.astype(jnp.float32)
    if w == 1:
        new_state = jnp.zeros((b, 0, c), x.dtype)
    elif valid is None:
        new_state = xp[:, -(w - 1):, :]
    else:
        # token j of x sits at xp[:, j + w - 1]; the state after `valid`
        # real tokens is xp[:, valid : valid + w - 1]
        idx = valid[:, None] + jnp.arange(w - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y.astype(x.dtype), new_state


def _mask_dt(dt, token_valid):
    """Zero dt at padded positions: decay=exp(0)=1 and input=0 make the
    selective-scan update a no-op there, so padded tails never touch the
    carried SSM state (same identity the internal chunk padding relies on)."""
    if token_valid is None:
        return dt
    s = dt.shape[1]
    mask = jnp.arange(s)[None, :] < token_valid[:, None]  # [B, S]
    return dt * mask[..., None]


# ---------------------------------------------------------------------------
# chunked selective scan core
# ---------------------------------------------------------------------------

def _combine(a, b_):
    return (a[0] * b_[0], a[1] * b_[0] + b_[1])


def _lazy_chunk_scan(make_chunk, n_chunks: int, h0, out_dim: int, dtype):
    """Chunked selective scan that NEVER materializes the full
    [B, S, *state] decay/input/state tensors (§Perf C1).

    ``make_chunk(ci) -> (decay, inp, project)`` builds the [B, c, *state]
    chunk tensors lazily (sliced from the raw dt/x/B/C projections inside
    the body) and ``project(h_states [B, c, *state]) -> y [B, c, out_dim]``
    contracts the states with C in-body, so only chunk-transient state ever
    exists; the scan carries h [B, *state] and emits y chunks.
    """
    def body(h, ci):
        decay, inp, project = make_chunk(ci)
        pd, pi = jax.lax.associative_scan(_combine, (decay, inp), axis=1)
        hs = pd * h[:, None] + pi
        return hs[:, -1], project(hs).astype(dtype)

    body = jax.checkpoint(body, prevent_cse=False)
    hS, ys = jax.lax.scan(body, h0, jnp.arange(n_chunks))
    # ys: [n, B, c, out_dim] -> [B, S, out_dim]
    ys = jnp.moveaxis(ys, 0, 1)
    b = ys.shape[0]
    return ys.reshape(b, -1, out_dim), hS


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------

def mamba_init(key, cfg, dtype=jnp.float32) -> Params:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    dt_rank = cfg.dt_rank
    ks = jax.random.split(key, 5)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": jnp.zeros((dc, di), dtype) + 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.dense_init(ks[1], di, dt_rank + 2 * ds, dtype=dtype),
        "dt_proj": L.dense_init(ks[2], dt_rank, di, bias=True, dtype=dtype),
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(ks[3], di, d, dtype=dtype),
    }


def mamba_apply(p: Params, x: jax.Array, cfg, *, cache=None, quant=None,
                token_valid=None):
    """x [B,S,D] -> (y [B,S,D], new_cache). cache={"conv","ssm"} for decode.

    ``token_valid`` [B]: per-row count of real (left-aligned) tokens; padded
    tail positions leave conv + SSM state untouched (chunked-prefill path).
    """
    b, s, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    tbl = L.make_table(x, quant)
    xz = L.lut_dense(p["in_proj"], x, quant, tbl)
    xp, z = jnp.split(xz, 2, axis=-1)
    xp = shard(xp, "batch", "seq", "model")

    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_dwconv(xp, p["conv_w"], p["conv_b"], conv_state,
                                  valid=token_valid)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    dbc = L.lut_dense(p["x_proj"], xc, quant)
    dt, bmat, cmat = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(
        L.lut_dense(p["dt_proj"], dt, quant).astype(jnp.float32))  # [B,S,di]
    dt = _mask_dt(dt, token_valid)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di, ds]
    xf = xc.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    h0 = (jnp.zeros((b, di, ds), jnp.float32) if cache is None
          else cache["ssm"].astype(jnp.float32))
    if s == 1:  # decode fast path, no chunking machinery
        decay1 = jnp.exp(dt[:, 0, :, None] * a[None])
        inp1 = (dt[:, 0] * xf[:, 0])[..., None] * bf[:, 0, None, :]
        hS = decay1 * h0 + inp1
        y = jnp.einsum("bdz,bz->bd", hS, cf[:, 0])[:, None]
    else:
        c = min(cfg.ssm_chunk, s)
        pad = (-s) % c
        if pad:  # zero-pad: decay=exp(0)=... dt=0 => decay=1, inp=0 (no-op)
            dt, xf2, bf, cf = (jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
                               for t in (dt, xf, bf, cf))
        else:
            xf2 = xf

        def make_chunk(ci):
            sl = lambda t: jax.lax.dynamic_slice_in_dim(t, ci * c, c, axis=1)
            dt_c, x_c, b_c, c_c = sl(dt), sl(xf2), sl(bf), sl(cf)
            decay = jnp.exp(dt_c[..., None] * a[None, None])   # [B,c,di,ds]
            inp = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
            proj = lambda hs: jnp.einsum("bcdz,bcz->bcd", hs, c_c)
            return decay, inp, proj

        y, hS = _lazy_chunk_scan(make_chunk, (s + pad) // c, h0, di,
                                 jnp.float32)
        y = y[:, :s]
    y = y + p["D"].astype(jnp.float32) * xf
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.lut_dense(p["out_proj"], y.astype(x.dtype), quant)
    new_cache = None if cache is None else {"conv": new_conv.astype(cache["conv"].dtype),
                                            "ssm": hS}
    return shard(y, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# Mamba2 (zamba2)
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg, dtype=jnp.float32) -> Params:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    nh = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di + 2 * ds + nh, dtype=dtype),
        "conv_w": jnp.zeros((dc, di), dtype) + 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.zeros((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "D": jnp.ones((nh,), dtype),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(ks[1], di, d, dtype=dtype),
    }


def mamba2_apply(p: Params, x: jax.Array, cfg, *, cache=None, quant=None,
                 token_valid=None):
    b, s, d = x.shape
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = di // nh
    tbl = L.make_table(x, quant)
    proj = L.lut_dense(p["in_proj"], x, quant, tbl)
    xp, z, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    xp = shard(xp, "batch", "seq", "model")

    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_dwconv(xp, p["conv_w"], p["conv_b"], conv_state,
                                  valid=token_valid)
    xc = jax.nn.silu(xc.astype(jnp.float32))

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,nh]
    dt = _mask_dt(dt, token_valid)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # [nh]
    xh = xc.reshape(b, s, nh, hd)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    h0 = (jnp.zeros((b, nh, hd, ds), jnp.float32) if cache is None
          else cache["ssm"].astype(jnp.float32))
    if s == 1:
        decay1 = jnp.exp(dt[:, 0] * a)[:, :, None, None]
        inp1 = (dt[:, 0, :, None] * xh[:, 0])[..., None] * bf[:, 0, None, None, :]
        hS = decay1 * h0 + inp1
        y = jnp.einsum("bhpz,bz->bhp", hS, cf[:, 0])[:, None]
    else:
        # SSD duality (§Perf C2): within a chunk the scalar-per-head decay
        # lets the recurrence collapse into attention-like matmuls —
        # scores[t,s] = (C_t·B_s)·exp(cum_t − cum_s) on the MXU; the
        # [c, hd, ds] state tensor is never materialized (only the
        # chunk-boundary carry is). exp arguments are ≤ 0 (a < 0): stable.
        c = min(cfg.ssm_chunk, s)
        pad = (-s) % c
        xh2, dt2, bf2, cf2 = xh, dt, bf, cf
        if pad:
            dt2 = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            xh2 = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            bf2 = jnp.pad(bf, ((0, 0), (0, pad), (0, 0)))
            cf2 = jnp.pad(cf, ((0, 0), (0, pad), (0, 0)))
        tri = jnp.tril(jnp.ones((c, c), bool))

        def body(h, ci):
            sl = lambda t: jax.lax.dynamic_slice_in_dim(t, ci * c, c, axis=1)
            dt_c, x_c, b_c, c_c = sl(dt2), sl(xh2), sl(bf2), sl(cf2)
            la = dt_c * a                      # [B,c,nh], <= 0
            cum = jnp.cumsum(la, axis=1)
            cb = jnp.einsum("btz,bsz->bts", c_c, b_c)
            w = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,t,s,nh]
            w = jnp.where(tri[None, :, :, None], w, 0.0)
            dtx = dt_c[..., None] * x_c        # [B,s,nh,hd]
            y_c = jnp.einsum("bts,btsh,bshp->bthp", cb, w, dtx)
            y_c += jnp.einsum("btz,bhpz,bth->bthp", c_c, h, jnp.exp(cum))
            wend = jnp.exp(cum[:, -1:, :] - cum)
            h_new = (jnp.exp(cum[:, -1])[:, :, None, None] * h
                     + jnp.einsum("bshp,bsz,bsh->bhpz", dtx, b_c, wend))
            return h_new, y_c

        body = jax.checkpoint(body, prevent_cse=False)
        hS, ys = jax.lax.scan(body, h0, jnp.arange((s + pad) // c))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s + pad, nh, hd)[:, :s]
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))  # gated
    # grouped RMSNorm before out-proj (mamba2 style)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_g"].astype(jnp.float32)
    y = L.lut_dense(p["out_proj"], y.astype(x.dtype), quant)
    new_cache = None if cache is None else {"conv": new_conv.astype(cache["conv"].dtype),
                                            "ssm": hS}
    return shard(y, "batch", "seq", None), new_cache
