"""Whisper-style encoder-decoder (whisper-tiny backbone).

The conv/mel frontend is a STUB per the assignment: ``batch["audio_frames"]``
supplies precomputed frame embeddings [B, n_frames, d_model].  Encoder:
bidirectional attention with sinusoidal positions.  Decoder: causal
self-attn + cross-attn to encoder output, learned positions (table sized to
the configured max sequence so the decode_32k shape is well-defined).
Whisper uses LayerNorm and non-gated GELU MLPs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_tree
from repro.models import kvcache, layers as L
from repro.models import transformer as TR

Params = Dict[str, Any]


def _sinusoid(n_pos: int, d: int):
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "attn": L.attention_init(k1, cfg, dtype=dtype),
        "mlp_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "attn": L.attention_init(k1, cfg, dtype=dtype),
        "xattn_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "xattn": L.attention_init(k2, cfg, dtype=dtype),
        "mlp_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    }


def init(key, cfg, dtype=None) -> Params:
    dtype = dtype or cfg.param_dtype
    k_e, k_enc, k_dec, k_p, k_h = jax.random.split(key, 5)
    ekeys = jax.random.split(k_enc, cfg.enc_layers)
    dkeys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": TR.embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
        "pos_embed": (jax.random.normal(k_p, (cfg.max_positions, cfg.d_model),
                                        jnp.float32) * 0.01).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(ekeys),
        "enc_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dkeys),
        "final_norm": L.norm_init(cfg.d_model, dtype, bias=True),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def encode(params: Params, frames: jax.Array, cfg) -> jax.Array:
    """frames [B, F, d_model] (stub frontend output) -> encoder states."""
    quant = cfg.quant
    h = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(c, lp):
        lp = constrain_tree(lp)  # §Perf T1
        a, _ = L.attention_apply(
            lp["attn"], L.layer_norm(lp["attn_norm"], c, cfg.norm_eps), cfg,
            causal=False, use_rope=False, quant=quant)
        c = c + a
        m = L.mlp_apply(lp["mlp"], L.layer_norm(lp["mlp_norm"], c, cfg.norm_eps),
                        quant)
        return c + m, None

    body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return L.layer_norm(params["enc_norm"], h, cfg.norm_eps)


def compute_cross_kv(params: Params, enc_out: jax.Array, cfg):
    b, f, _ = enc_out.shape

    def one(lp):
        k = L.lut_dense(lp["xattn"]["wk"], enc_out, cfg.quant)
        v = L.lut_dense(lp["xattn"]["wv"], enc_out, cfg.quant)
        return (k.reshape(b, f, cfg.n_kv_heads, cfg.head_dim),
                v.reshape(b, f, cfg.n_kv_heads, cfg.head_dim))

    return jax.lax.map(one, params["dec_layers"])


def forward(params: Params, batch, cfg, *, caches=None, cache_pos=0,
            window=None, token_valid=None,
            page_table=None) -> Tuple[jax.Array, Any, Dict]:
    del token_valid  # attention-only stack: see transformer.forward
    tokens = batch["tokens"]
    quant = cfg.quant
    b, s = tokens.shape
    h = TR.embed_apply(params["embed"], tokens).astype(cfg.activation_dtype)
    cp = jnp.asarray(cache_pos)
    if cp.ndim == 1:  # per-slot decode positions
        pos = cp[:, None] + jnp.arange(s)  # [B, S]
        h = h + jnp.take(params["pos_embed"], pos, axis=0).astype(h.dtype)
    else:
        pos = cp + jnp.arange(s)
        h = h + jnp.take(params["pos_embed"], pos, axis=0)[None].astype(h.dtype)

    if "audio_frames" in batch:  # prefill/train: run the encoder
        enc_out = encode(params,
                         batch["audio_frames"].astype(cfg.activation_dtype), cfg)
        cross_kv = compute_cross_kv(params, enc_out, cfg)
    else:  # decode: reuse the cached encoder KV
        cross_kv = caches["cross_kv"]
    self_caches = None if caches is None else caches["kv"]

    def body(carry, xs):
        hh = carry
        if self_caches is None:
            lp, (xk, xv) = xs
            lc = None
        else:
            lp, (xk, xv), lc = xs
        lp = constrain_tree(lp)  # §Perf T1
        a, nc = L.attention_apply(
            lp["attn"], L.layer_norm(lp["attn_norm"], hh, cfg.norm_eps), cfg,
            kv_cache=lc, cache_pos=cache_pos, use_rope=False, quant=quant,
            page_table=page_table)
        hh = hh + a
        xa, _ = L.attention_apply(
            lp["xattn"], L.layer_norm(lp["xattn_norm"], hh, cfg.norm_eps), cfg,
            xattn_kv=(xk.astype(hh.dtype), xv.astype(hh.dtype)),
            causal=False, use_rope=False, quant=quant)
        hh = hh + xa
        m = L.mlp_apply(lp["mlp"], L.layer_norm(lp["mlp_norm"], hh, cfg.norm_eps),
                        quant)
        return hh + m, nc

    body = jax.checkpoint(body, prevent_cse=False)
    xs = ((params["dec_layers"], cross_kv) if self_caches is None
          else (params["dec_layers"], cross_kv, self_caches))
    h, new_self = jax.lax.scan(body, h, xs)

    h = L.layer_norm(params["final_norm"], h, cfg.norm_eps)
    logits = TR.head_apply(params["lm_head"], h, quant)
    new_caches = None
    if caches is not None:
        new_caches = {"kv": new_self, "cross_kv": cross_kv}
    return logits, new_caches, {}


def init_cache(cfg, batch: int, s_cache: int, window=None, dtype=jnp.bfloat16,
               cross_kv=None):
    caches = {"kv": kvcache.attn_cache(cfg.n_layers, batch, s_cache,
                                       cfg.n_kv_heads, cfg.head_dim, dtype,
                                       window)}
    if cross_kv is None:
        ckv = jnp.zeros((cfg.n_layers, batch, cfg.n_audio_frames,
                         cfg.n_kv_heads, cfg.head_dim), dtype)
        cross_kv = (ckv, ckv)
    caches["cross_kv"] = cross_kv
    return caches
