"""Mixture-of-Experts LM (olmoe, kimi-k2) with sort-based EP dispatch.

Routing: token-choice top-k, fp32 router (accuracy-critical, never
quantized — DESIGN.md §5).  Dispatch avoids [T, E] one-hot tensors (E up to
384): the T·k assignments are argsorted by expert id, positions within an
expert come from a cumsum over bincounts, and tokens scatter-add into a
capacity-bucketed [E, C, D] buffer (dropped tokens write zeros; no write
collisions among kept tokens).  Expert FFNs run as one batched einsum with
the expert dim sharded over the EP axis — under pjit the scatter/gather
become the all-to-alls.

Aux outputs: load-balance loss (Switch-style E·Σ f_e·P_e) and router-z loss.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core.quantize import fake_quant
from repro.distributed._compat import shard_map
from repro.distributed.sharding import constrain_tree, shard
from repro.models import kvcache, layers as L
from repro.models import transformer as TR

Params = Dict[str, Any]


def _expert_init(key, e: int, d_in: int, d_out: int, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * scale).astype(dtype)


def moe_mlp_init(key, cfg, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02)
                   .astype(jnp.float32)},  # router stays fp32
        "experts": {
            "gate": _expert_init(ks[1], e, d, f, dtype),
            "up": _expert_init(ks[2], e, d, f, dtype),
            "down": _expert_init(ks[3], e, f, d, dtype),
        },
    }
    if cfg.n_shared_experts:
        p["shared_mlp"] = L.mlp_init(ks[4], d, f * cfg.n_shared_experts,
                                     dtype=dtype)
    return p


def _capacity(t: int, k: int, e: int, factor: float) -> int:
    c = int(math.ceil(t * k * factor / e))
    return max(8, -(-c // 8) * 8)


def _expert_ffn(w, x, quant):
    """Batched expert einsum with optional QAT fake-quant on expert weights."""
    if "gate_qw" in w:  # packed low-bit experts (serving path)
        from repro.core.mpgemm import mpgemm, precompute_tables
        mode = (quant or {}).get("mpgemm_mode", "lut_xla")
        tq = (quant or {}).get("table_quant", "per_row")
        kg = (quant or {}).get("k_group", 4)
        fusion = (quant or {}).get("fusion", "auto")
        # fused lut_pallas rebuilds tables in-VMEM — sharing one via HBM
        # would force the staged path; resolve auto/tuned the same way
        # layers do (tuned consults the autotune cache, heuristic on miss;
        # x is [E, C, D]: per-expert tables are [C, D]-shaped)
        share = mode == "lut_xla" or (
            mode == "lut_pallas"
            and L.resolve_fusion(x.shape[1], x.shape[2], quant or {})
            == "staged")

        def one(xe, gq, uq, dq):
            tbl = precompute_tables(xe, kg, tq) if share else None
            g = mpgemm(xe, gq, mode=mode, table_quant=tq, table=tbl,
                       fusion=fusion)
            u = mpgemm(xe, uq, mode=mode, table_quant=tq, table=tbl,
                       fusion=fusion)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
            return mpgemm(h, dq, mode=mode, table_quant=tq, fusion=fusion)

        return jax.vmap(one)(x, w["gate_qw"], w["up_qw"], w["down_qw"])
    gate, up, down = w["gate"], w["up"], w["down"]
    if quant and quant.get("qat"):
        bits = quant.get("weight_bits", 2)
        scheme = quant.get("scheme", "symmetric")
        # per-output-channel along the contraction dim
        gate = jnp.swapaxes(fake_quant(jnp.swapaxes(gate, 1, 2), bits, scheme), 1, 2)
        up = jnp.swapaxes(fake_quant(jnp.swapaxes(up, 1, 2), bits, scheme), 1, 2)
        down = jnp.swapaxes(fake_quant(jnp.swapaxes(down, 1, 2), bits, scheme), 1, 2)
    g = jnp.einsum("ecd,edf->ecf", x, gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", x, up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, down.astype(x.dtype))


def _moe_mlp_shardmap(p: Params, x: jax.Array, cfg, quant, plan):
    """EP dispatch under shard_map (§Perf A1): routing is LOCAL per data
    shard, experts live on the model axis, and the ONLY collective is the
    final psum of partial outputs over the model axis (plus FSDP weight
    gathers for huge expert stacks).

    Under plain pjit the global scatter/gather dispatch replicates the
    [E·C, D] buffers through all-gathers/all-reduces (measured: olmoe
    train_4k spent 16.8 s/step in collectives — 134x its compute term).
    Dropping is per-(data shard, expert) with capacity T_loc·k·cf/E.
    """
    mesh = plan.mesh
    model_ax = plan.model
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    mp_ = sizes.get(model_ax, 1)
    batch_axes = plan.batch
    dp_ = 1
    for a in batch_axes:
        dp_ *= sizes.get(a, 1)
    e_loc = e // mp_
    t_loc = t // dp_
    cap = _capacity(t_loc, k, e, cfg.capacity_factor)
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    fsdp_ax = plan.fsdp

    # in_specs: tokens batch-sharded; router replicated; experts E-sharded
    # over model (+ d_model over fsdp when enabled)
    xspec = P(bspec, None)
    espec = P(model_ax, fsdp_ax, None)
    dspec = P(model_ax, None, fsdp_ax)

    def body(xf, rw, gate, up, down, shared):
        # local routing
        logits = jnp.dot(xf.astype(jnp.float32), rw)          # [T_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
        midx = jax.lax.axis_index(model_ax)
        e0 = midx * e_loc
        eid = top_i.reshape(-1)
        mine = (eid >= e0) & (eid < e0 + e_loc)
        eid_loc = jnp.where(mine, eid - e0, e_loc)            # e_loc = trash
        order = jnp.argsort(eid_loc)
        sorted_eid = eid_loc[order]
        counts = jnp.bincount(eid_loc, length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(t_loc * k) - starts[sorted_eid]
        keep = (pos_in_e < cap) & (sorted_eid < e_loc)
        slot = jnp.minimum(sorted_eid, e_loc - 1) * cap + \
            jnp.minimum(pos_in_e, cap - 1)
        tok = order // k
        disp = jnp.zeros((e_loc * cap, d), xf.dtype)
        disp = disp.at[slot].add(jnp.where(keep[:, None], xf[tok], 0))

        if fsdp_ax:  # FSDP: gather this layer's expert shards over data
            gate = jax.lax.all_gather(gate, fsdp_ax, axis=1, tiled=True)
            up = jax.lax.all_gather(up, fsdp_ax, axis=1, tiled=True)
            down = jax.lax.all_gather(down, fsdp_ax, axis=2, tiled=True)
        out = _expert_ffn({"gate": gate, "up": up, "down": down},
                          disp.reshape(e_loc, cap, d), quant)
        out = out.reshape(e_loc * cap, d)

        gathered = jnp.where(keep[:, None], out[slot], 0)
        wsorted = top_p.reshape(-1)[order]
        y = jnp.zeros((t_loc, d), jnp.float32).at[tok].add(
            gathered.astype(jnp.float32) * wsorted[:, None])
        y = jax.lax.psum(y, model_ax)  # combine expert partials

        if shared is not None:
            sh_out = L.mlp_apply(shared, xf[None], quant)[0]
            y = y + sh_out.astype(jnp.float32)

        # aux: pmean the routing statistics BEFORE combining (mean of
        # products != product of means)
        f_e = jax.lax.pmean(jnp.mean(jax.nn.one_hot(top_i, e, dtype=jnp.float32),
                                     axis=(0, 1)) * e, batch_axes)
        p_e = jax.lax.pmean(jnp.mean(probs, axis=0), batch_axes)
        lb = e * jnp.sum(f_e / e * p_e)
        zl = jax.lax.pmean(
            jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
            batch_axes)
        return y.astype(xf.dtype), lb, zl

    # shared-expert MLP weights: replicated (small vs the expert stacks)
    shared = p.get("shared_mlp")
    shared_spec = None if shared is None else jax.tree.map(
        lambda _: P(), shared)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(), espec, espec, dspec, shared_spec),
        out_specs=(xspec, P(), P()),
        check_vma=False)
    y, lb, zl = fn(x.reshape(t, d), p["router"]["w"],
                   p["experts"]["gate"], p["experts"]["up"],
                   p["experts"]["down"], shared)
    return y.reshape(b, s, d), {"lb_loss": lb, "router_z_loss": zl}


def moe_mlp_apply(p: Params, x: jax.Array, cfg, quant=None):
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k

    from repro.distributed.sharding import current_plan
    plan = current_plan()
    if plan is not None and "gate" in p.get("experts", {}):
        sizes = dict(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
        mp_ = sizes.get(plan.model, 1)
        dp_ = 1
        for a in plan.batch:
            dp_ *= sizes.get(a, 1)
        if e % mp_ == 0 and t % dp_ == 0 and mp_ > 1:
            return _moe_mlp_shardmap(p, x, cfg, quant, plan)

    xf = x.reshape(t, d)

    logits = jnp.dot(xf.astype(jnp.float32), p["router"]["w"])  # [T, E] fp32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ------------------------------------------------
    eid = top_i.reshape(-1)                            # [T*k]
    order = jnp.argsort(eid)                           # stable
    sorted_eid = eid[order]
    counts = jnp.bincount(eid, length=e)               # [E]
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_eid]
    cap = _capacity(t, k, e, cfg.capacity_factor)
    keep = pos_in_e < cap
    slot = sorted_eid * cap + jnp.minimum(pos_in_e, cap - 1)
    tok = order // k                                   # source token per assign

    disp = jnp.zeros((e * cap, d), x.dtype)
    disp = disp.at[slot].add(jnp.where(keep[:, None], xf[tok], 0))
    disp = shard(disp.reshape(e, cap, d), "expert", None, None)

    out = _expert_ffn(p["experts"], disp, quant)       # [E, C, D]
    out = shard(out, "expert", None, None).reshape(e * cap, d)

    # ---- combine ------------------------------------------------------------
    gathered = jnp.where(keep[:, None], out[slot], 0)  # [T*k, D]
    wsorted = top_p.reshape(-1)[order]
    y = jnp.zeros((t, d), jnp.float32).at[tok].add(
        gathered.astype(jnp.float32) * wsorted[:, None])

    if "shared_mlp" in p:
        y = y + L.mlp_apply(p["shared_mlp"], xf, quant).astype(jnp.float32)

    # ---- aux losses ---------------------------------------------------------
    f_e = jnp.mean(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1)) * e
    p_e = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(f_e / e * p_e)  # Switch-style
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"lb_loss": lb_loss, "router_z_loss": z_loss}
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# MoE block + LM
# ---------------------------------------------------------------------------

def block_init(key, cfg, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg, dtype=dtype),
        "mlp_norm": L.norm_init(cfg.d_model, dtype),
        "moe": moe_mlp_init(k2, cfg, dtype),
    }


def block_apply(p: Params, h: jax.Array, cfg, *, cache=None, cache_pos=0,
                window=None, quant=None, page_table=None):
    a, cache = L.attention_apply(
        p["attn"], L.rms_norm(p["attn_norm"], h, cfg.norm_eps), cfg,
        kv_cache=cache, cache_pos=cache_pos, window=window, quant=quant,
        page_table=page_table)
    h = shard(h + a, "batch", "seq", None)
    m, aux = moe_mlp_apply(p["moe"], L.rms_norm(p["mlp_norm"], h, cfg.norm_eps),
                           cfg, quant)
    return shard(h + m, "batch", "seq", None), cache, aux


def _scan_block(p, h, cfg, cache, cache_pos, window, quant, page_table=None):
    h, cache, aux = block_apply(p, h, cfg, cache=cache, cache_pos=cache_pos,
                                window=window, quant=quant,
                                page_table=page_table)
    return h, cache, aux


def init(key, cfg, dtype=None) -> Params:
    dtype = dtype or cfg.param_dtype
    k_e, k_d, k_l, k_h = jax.random.split(key, 4)
    params = {
        "embed": TR.embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
        "layers": TR.stack_init(k_l, cfg, cfg.n_layers - cfg.first_dense_layers,
                                block_init_fn=block_init, dtype=dtype),
        "final_norm": L.norm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }
    if cfg.first_dense_layers:
        dcfg_ff = cfg.dense_d_ff or cfg.d_ff
        keys = jax.random.split(k_d, cfg.first_dense_layers)
        params["dense_layers"] = jax.vmap(
            lambda k: {
                "attn_norm": L.norm_init(cfg.d_model, dtype),
                "attn": L.attention_init(jax.random.fold_in(k, 0), cfg, dtype=dtype),
                "mlp_norm": L.norm_init(cfg.d_model, dtype),
                "mlp": L.mlp_init(jax.random.fold_in(k, 1), cfg.d_model,
                                  dcfg_ff, dtype=dtype),
            })(keys)
    return params


def forward(params: Params, batch, cfg, *, caches=None, cache_pos=0,
            window=None, token_valid=None,
            page_table=None) -> Tuple[jax.Array, Any, Dict]:
    del token_valid  # attention-only stack: see transformer.forward
    tokens = batch["tokens"]
    quant = cfg.quant
    h = TR.embed_apply(params["embed"], tokens).astype(cfg.activation_dtype)

    nd = cfg.first_dense_layers
    dense_caches = moe_caches = None
    if caches is not None:
        dense_caches = jax.tree.map(lambda c: c[:nd], caches)
        moe_caches = jax.tree.map(lambda c: c[nd:], caches)

    new_dense = None
    if nd:
        def dbody(carry, xs):
            hh = carry
            lp = xs if dense_caches is None else xs[0]
            lp = constrain_tree(lp)  # §Perf T1
            lc = None if dense_caches is None else xs[1]
            hh, nc = TR.block_apply(lp, hh, cfg, cache=lc, cache_pos=cache_pos,
                                    window=window, quant=quant,
                                    page_table=page_table)
            return hh, nc
        dbody = jax.checkpoint(dbody, prevent_cse=False)
        xs = (params["dense_layers"] if dense_caches is None
              else (params["dense_layers"], dense_caches))
        h, new_dense = jax.lax.scan(dbody, h, xs)

    def body(carry, xs):
        hh, lb, zl = carry
        lp = xs if moe_caches is None else xs[0]
        lp = constrain_tree(lp)  # §Perf T1
        lc = None if moe_caches is None else xs[1]
        hh, nc, aux = _scan_block(lp, hh, cfg, lc, cache_pos, window, quant,
                                  page_table)
        return (hh, lb + aux["lb_loss"], zl + aux["router_z_loss"]), nc

    body = jax.checkpoint(body, prevent_cse=False)
    xs = params["layers"] if moe_caches is None else (params["layers"], moe_caches)
    (h, lb, zl), new_moe = jax.lax.scan(body, (h, 0.0, 0.0), xs)

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = TR.head_apply(params["lm_head"], h, quant)
    n_moe = cfg.n_layers - nd
    aux = {"lb_loss": lb / n_moe, "router_z_loss": zl / n_moe}
    new_caches = None
    if caches is not None:
        if nd:
            new_caches = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), new_dense, new_moe)
        else:
            new_caches = new_moe
    return logits, new_caches, aux


def init_cache(cfg, batch: int, s_cache: int, window=None, dtype=jnp.bfloat16):
    return kvcache.attn_cache(cfg.n_layers, batch, s_cache, cfg.n_kv_heads,
                              cfg.head_dim, dtype, window)
