"""Model API: family dispatch, param init (float / quantized-serving),
input specs (ShapeDtypeStruct stand-ins for the dry-run), cache init.

``input_specs(cfg, shape)`` follows the shannon/kernels pattern: weak-type-
correct, shardable, zero device allocation.  Modality frontends are stubs —
VLM gets patch embeddings, audio gets frame embeddings (see DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig, ShapeSpec
from repro.models import audio, hybrid, moe, ssm, transformer, vlm
from repro.models import kvcache, layers as L, quantized
from repro.distributed.sharding import constrain_tree, shard

_FAMILY = {
    "dense": transformer,
    "moe": moe,
    "hybrid": hybrid,
    "vlm": vlm,
    "audio": audio,
}


def get_module(family: str):
    if family == "ssm":
        return _SsmLM
    return _FAMILY[family]


# ---------------------------------------------------------------------------
# SSM LM (falcon-mamba): mamba1 blocks in the standard stack
# ---------------------------------------------------------------------------

class _SsmLM:
    """Namespace-style module matching transformer.py's interface."""

    @staticmethod
    def _block_init(key, cfg, dtype):
        return {"norm": L.norm_init(cfg.d_model, dtype),
                "ssm": ssm.mamba_init(key, cfg, dtype)}

    @staticmethod
    def init(key, cfg, dtype=None):
        dtype = dtype or cfg.param_dtype
        k_e, k_l, k_h = jax.random.split(key, 3)
        keys = jax.random.split(k_l, cfg.n_layers)
        return {
            "embed": transformer.embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
            "layers": jax.vmap(lambda k: _SsmLM._block_init(k, cfg, dtype))(keys),
            "final_norm": L.norm_init(cfg.d_model, dtype),
            "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype=dtype),
        }

    @staticmethod
    def forward(params, batch, cfg, *, caches=None, cache_pos=0, window=None,
                token_valid=None, page_table=None):
        del page_table  # SSM state is O(1)/slot: nothing to page
        h = transformer.embed_apply(params["embed"], batch["tokens"])
        h = h.astype(cfg.activation_dtype)

        def body(carry, xs):
            hh = carry
            lp = xs if caches is None else xs[0]
            lp = constrain_tree(lp)  # §Perf T1
            lc = None if caches is None else xs[1]
            y, nc = ssm.mamba_apply(lp["ssm"],
                                    L.rms_norm(lp["norm"], hh, cfg.norm_eps),
                                    cfg, cache=lc, quant=cfg.quant,
                                    token_valid=token_valid)
            return hh + y, nc

        body = jax.checkpoint(body, prevent_cse=False)
        xs = params["layers"] if caches is None else (params["layers"], caches)
        h, new_caches = jax.lax.scan(body, h, xs)
        h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
        logits = transformer.head_apply(params["lm_head"], h, cfg.quant)
        return logits, new_caches, {}

    @staticmethod
    def init_cache(cfg, batch, s_cache, window=None, dtype=jnp.bfloat16):
        return kvcache.mamba_cache(cfg.n_layers, batch, cfg.d_inner,
                                   cfg.ssm_state, cfg.d_conv)


# ---------------------------------------------------------------------------
# unified entry points
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, *, serve_quantized: bool = False):
    """Float params; with serve_quantized=True, projections become packed
    low-bit QuantizedWeights per cfg.quant (the paper's serving format)."""
    params = get_module(cfg.family).init(key, cfg)
    if serve_quantized and cfg.quant:
        params = quantized.quantize_params(params, cfg.quant)
    return params


def forward(params, batch, cfg: ArchConfig, **kw):
    return get_module(cfg.family).forward(params, batch, cfg, **kw)


def init_cache(cfg: ArchConfig, batch: int, s_cache: int, window=None,
               dtype=None):
    if dtype is None:
        dtype = "int8" if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    return get_module(cfg.family).init_cache(cfg, batch, s_cache,
                                             window=window, dtype=dtype)


# ---------------------------------------------------------------------------
# dry-run specs (no allocation)
# ---------------------------------------------------------------------------

def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_specs(cfg: ArchConfig, *, serve_quantized: bool = False):
    fn = functools.partial(init_params, cfg=cfg, serve_quantized=serve_quantized)
    return _sds(jax.eval_shape(fn, jax.random.key(0)))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok),
                 "labels": jax.ShapeDtypeStruct((b, s), tok)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
    else:  # decode: one new token against an s-long cache
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), tok),
                 "cache_pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["audio_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return specs


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs of the decode-state for this shape."""
    fn = functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len,
                           window=shape.window)
    return _sds(jax.eval_shape(fn))
