"""Zamba2-style hybrid: mamba2 backbone + one *shared* attention block.

Structure: ``n_groups`` groups of (``attn_every`` mamba2 layers, then the
shared attention+MLP block), plus a tail of leftover mamba2 layers.  The
shared block has ONE set of weights reused at every invocation (zamba2's
parameter-efficiency trick) but each invocation owns a separate KV cache
(stacked [n_groups, ...]).

The mamba params are stacked [n_groups, attn_every, ...] so the forward is a
scan over groups with an inner scan over the group's mamba layers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_tree, shard
from repro.models import kvcache, layers as L, ssm
from repro.models import transformer as TR

Params = Dict[str, Any]


def _shared_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_init(cfg.d_model, dtype),
        "shared_attn": L.attention_init(k1, cfg, dtype=dtype),
        "mlp_norm": L.norm_init(cfg.d_model, dtype),
        "shared_mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def _mamba_layer_init(key, cfg, dtype):
    return {"norm": L.norm_init(cfg.d_model, dtype),
            "ssm": ssm.mamba2_init(key, cfg, dtype)}


def init(key, cfg, dtype=None) -> Params:
    dtype = dtype or cfg.param_dtype
    k_e, k_m, k_t, k_s, k_h = jax.random.split(key, 5)
    n_groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    gkeys = jax.random.split(k_m, n_groups * cfg.attn_every).reshape(
        n_groups, cfg.attn_every)
    params = {
        "embed": TR.embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
        "groups": jax.vmap(jax.vmap(lambda k: _mamba_layer_init(k, cfg, dtype)))(gkeys),
        "shared": _shared_block_init(k_s, cfg, dtype),
        "final_norm": L.norm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }
    if tail:
        tkeys = jax.random.split(k_t, tail)
        params["tail"] = jax.vmap(lambda k: _mamba_layer_init(k, cfg, dtype))(tkeys)
    return params


def _mamba_layer_apply(p, h, cfg, cache, quant, token_valid=None):
    y, nc = ssm.mamba2_apply(p["ssm"], L.rms_norm(p["norm"], h, cfg.norm_eps),
                             cfg, cache=cache, quant=quant,
                             token_valid=token_valid)
    return shard(h + y, "batch", "seq", None), nc


def _shared_apply(p, h, cfg, kv, cache_pos, window, quant, page_table=None):
    a, kv = L.attention_apply(
        p["shared_attn"], L.rms_norm(p["attn_norm"], h, cfg.norm_eps), cfg,
        kv_cache=kv, cache_pos=cache_pos, window=window, quant=quant,
        page_table=page_table)
    h = shard(h + a, "batch", "seq", None)
    m = L.mlp_apply(p["shared_mlp"], L.rms_norm(p["mlp_norm"], h, cfg.norm_eps),
                    quant)
    return shard(h + m, "batch", "seq", None), kv


def forward(params: Params, batch, cfg, *, caches=None, cache_pos=0,
            window=None, token_valid=None,
            page_table=None) -> Tuple[jax.Array, Any, Dict]:
    # token_valid [B]: real-token counts for right-padded chunked prefill —
    # consumed by the mamba2 layers (state masking); the shared attention
    # block needs no masking (see transformer.forward).
    tokens = batch["tokens"]
    quant = cfg.quant
    h = TR.embed_apply(params["embed"], tokens).astype(cfg.activation_dtype)
    n_groups = cfg.n_layers // cfg.attn_every

    gm_caches = kv_caches = tail_caches = None
    if caches is not None:
        gm_caches, kv_caches, tail_caches = (
            caches["mamba"], caches["kv"], caches.get("tail"))

    def group_body(carry, xs):
        hh = carry
        if gm_caches is None:
            gp = xs
            mcache = None
        else:
            gp, (mcache, kvc) = xs[0], (xs[1], xs[2])

        def inner(c, lxs):
            lp = lxs if mcache is None else lxs[0]
            lp = constrain_tree(lp)  # §Perf T1
            lc = None if mcache is None else lxs[1]
            c2, nc = _mamba_layer_apply(lp, c, cfg, lc, quant, token_valid)
            return c2, nc

        inner = jax.checkpoint(inner, prevent_cse=False)
        ixs = gp if mcache is None else (gp, mcache)
        hh, new_m = jax.lax.scan(inner, hh, ixs)
        kvc_in = None if gm_caches is None else kvc
        hh, new_kv = _shared_apply(params["shared"], hh, cfg, kvc_in,
                                   cache_pos, window, quant, page_table)
        if gm_caches is None:
            return hh, None
        return hh, (new_m, new_kv)

    group_body = jax.checkpoint(group_body, prevent_cse=False)
    xs = (params["groups"] if gm_caches is None
          else (params["groups"], gm_caches, kv_caches))
    h, new_group_caches = jax.lax.scan(group_body, h, xs)

    new_tail = None
    if "tail" in params:
        def tbody(c, lxs):
            lp = lxs if tail_caches is None else lxs[0]
            lp = constrain_tree(lp)  # §Perf T1
            lc = None if tail_caches is None else lxs[1]
            return _mamba_layer_apply(lp, c, cfg, lc, quant, token_valid)
        tbody = jax.checkpoint(tbody, prevent_cse=False)
        txs = params["tail"] if tail_caches is None else (params["tail"], tail_caches)
        h, new_tail = jax.lax.scan(tbody, h, txs)

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = TR.head_apply(params["lm_head"], h, quant)
    new_caches = None
    if caches is not None:
        new_m, new_kv = new_group_caches
        new_caches = {"mamba": new_m, "kv": new_kv}
        if new_tail is not None:
            new_caches["tail"] = new_tail
    return logits, new_caches, {}


def init_cache(cfg, batch: int, s_cache: int, window=None, dtype=jnp.bfloat16):
    n_groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    hd = cfg.d_inner // cfg.ssm_heads
    m = kvcache.mamba2_cache(n_groups * cfg.attn_every, batch, cfg.ssm_heads,
                             hd, cfg.ssm_state, cfg.d_inner, cfg.d_conv)
    m = jax.tree.map(
        lambda c: c.reshape((n_groups, cfg.attn_every) + c.shape[1:]), m)
    caches = {
        "mamba": m,
        "kv": kvcache.attn_cache(n_groups, batch, s_cache, cfg.n_kv_heads,
                                 cfg.head_dim, dtype, window),
    }
    if tail:
        caches["tail"] = kvcache.mamba2_cache(
            tail, batch, cfg.ssm_heads, hd, cfg.ssm_state, cfg.d_inner,
            cfg.d_conv)
    return caches
