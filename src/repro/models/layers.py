"""Shared model layers: norms, RoPE, LutDense (the paper's integration
point), flash-style chunked attention, and gated MLPs.

Every projection in every architecture goes through :func:`lut_dense`, which
dispatches on the parameter form:

  * float ``{"w": [in, out]}``      — dense GEMM; optional QAT fake-quant of
    the weight in the forward pass (STE), the paper's §5 training story;
  * quantized ``{"qw": QuantizedWeight}`` — mpGEMM via repro.core.mpgemm in
    the configured mode (dequant / lut_xla / lut_pallas).

Projections sharing an input (QKV; gate+up) share one precomputed lookup
table — the DFG-transform + broadcast amortization of §3.1.1.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import mpgemm as mp
from repro.core.quantize import fake_quant
from repro.distributed._compat import shard_map
from repro.distributed.sharding import current_plan
from repro.models import kvcache

Params = Dict[str, Any]


def _quantize_kv_slice(x):
    """bf16 [B,S,KV,hd] -> (int8 codes, f32 scales [B,S,KV,1]) absmax."""
    sc = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), -1,
                             keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sc), -127, 127
                 ).astype(jnp.int8)
    return q, sc


def _flash_decode_ok(plan, kv_cache, b, s, window, per_slot):
    if plan is None or kv_cache is None or s != 1 or window or per_slot:
        return False
    sizes = dict(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
    mp_size = sizes.get(plan.model, 1)
    bsz = 1
    for a in plan.batch:
        bsz *= sizes.get(a, 1)
    s_max = kv_cache[0].shape[1]
    return mp_size > 1 and s_max % mp_size == 0 and b % bsz == 0


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def norm_init(d: int, dtype=jnp.float32, bias: bool = False) -> Params:
    p = {"g": jnp.ones((d,), dtype)}
    if bias:
        p["b"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# LutDense — every matmul in the framework
# ---------------------------------------------------------------------------

def lut_dense(p: Params, x: jax.Array, quant: Optional[dict] = None,
              table=None) -> jax.Array:
    """y = x @ W (+b). See module docstring for the dispatch rule."""
    if "qw" in p:  # packed low-bit weights -> mpGEMM
        q = quant or {}
        y = mp.mpgemm(
            x, p["qw"],
            mode=q.get("mpgemm_mode", "lut_xla"),
            table_quant=q.get("table_quant", "per_row"),
            table=table,
            fusion=q.get("fusion", "auto"),
        )
    else:
        w = p["w"]
        if quant and quant.get("qat"):
            # fake-quant along the input axis per output channel
            w = fake_quant(w.T, quant.get("weight_bits", 2),
                           quant.get("scheme", "symmetric")).T
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32)
        y = y.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def resolve_fusion(m: int, k: int, quant: dict) -> str:
    """Resolve the lut_pallas ``fusion`` knob to "fused"/"staged" for a table
    shared across consumers of one [m, k] activation.

    Delegates to ops.auto_fusion (the same clamp + scheduler decision the
    per-call dispatch uses) with one approximation: N differs per consumer,
    so the decision uses the scheduler's maximum elongation (n=2048) —
    ``fused_tile_bytes`` only grows with bn, so fused fitting there implies
    it fits for every real consumer with the same clamped bm/bg. With
    ``fusion="tuned"`` the autotune cache votes first (largest-N entry
    matching this activation shape); a miss falls back to the heuristic.
    """
    fusion = quant.get("fusion", "auto")
    if fusion not in ("auto", "tuned"):
        return fusion
    kg = quant.get("k_group", 4)
    bits = quant.get("weight_bits", 2)
    if fusion == "tuned":
        from repro.core.autotune import lookup_fusion_any
        tuned = lookup_fusion_any(m, max(1, k // kg), kg, bits)
        if tuned is not None:
            return tuned
        # miss: no active cache or shape untuned — same fallback as ops
    from repro.kernels.ops import auto_fusion
    return auto_fusion(m, 2048, max(1, k // kg), kg, bits)


def make_table(x: jax.Array, quant: Optional[dict]):
    """Precompute a shared lookup table for all consumers of ``x`` (§3.1.1).

    Returns None unless the quant config uses a LUT mode — dense and dequant
    paths have no table. Also None when the Pallas path will run the fused
    kernel (``fusion="fused"``, or ``"auto"`` resolving to fused): the fused
    kernel rebuilds the table in-VMEM per consumer (§3.1.1 fused DFG), so a
    shared HBM table would defeat the point — and supplying one would force
    ops.lut_mpgemm onto the staged path, making the knob a no-op. Consumers
    that share an input instead amortize the (cheap, depth-K) MXU recompute.
    """
    if not quant:
        return None
    if quant.get("mpgemm_mode") not in ("lut_xla", "lut_pallas"):
        return None
    if quant.get("mpgemm_mode") == "lut_pallas":
        m = max(1, math.prod(x.shape[:-1]))
        if resolve_fusion(m, x.shape[-1], quant) == "fused":
            return None
    return mp.precompute_tables(
        x, quant.get("k_group", 4), quant.get("table_quant", "per_row"))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["g"].astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["g"].astype(jnp.float32)
    if "b" in p:
        out = out + p["b"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x [B, S, H, hd], positions [B, S] (or [S]) -> rotated."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention (online softmax over KV chunks)
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,                # [B, Sq, H, hd]
    k: jax.Array,                # [B, Skv, KV, hd]
    v: jax.Array,                # [B, Skv, KV, hd]
    *,
    q_offset: jax.Array | int = 0,   # global position of q[:, 0]
    kv_offset: jax.Array | int = 0,  # global position of k[:, 0]
    causal: bool = True,
    window: Optional[int] = None,    # sliding window (global positions)
    kv_valid_len: Optional[jax.Array] = None,  # [B] or scalar valid cache len
    chunk: int = 1024,
    k_scale: Optional[jax.Array] = None,  # [B, Skv, KV, 1] int8-cache scales
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Never materializes the [Sq, Skv] score matrix: lax.scan over KV chunks
    with online softmax. Handles GQA by head-grouping (no KV repeat)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    qg = q.reshape(b, sq, kv, rep, hd)
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # NOTE: chunks are taken with dynamic_slice inside the scan body — never
    # pre-split/transposed — so the KV cache is streamed once, with no
    # cache-sized temp (§Perf iteration 1).

    q_pos = (jnp.asarray(q_offset) + jnp.arange(sq))  # [Sq] global
    neg = jnp.finfo(jnp.float32).min

    def body(carry, ci):
        m, l, acc = carry
        kci = jax.lax.dynamic_slice_in_dim(k, ci * chunk, chunk, axis=1)
        vci = jax.lax.dynamic_slice_in_dim(v, ci * chunk, chunk, axis=1)
        kcf = kci.astype(jnp.float32)
        vcf = vci.astype(jnp.float32)
        if k_scale is not None:  # int8 cache: dequantize the chunk in-loop
            kcf = kcf * jax.lax.dynamic_slice_in_dim(k_scale, ci * chunk,
                                                     chunk, axis=1)
            vcf = vcf * jax.lax.dynamic_slice_in_dim(v_scale, ci * chunk,
                                                     chunk, axis=1)
        kv_pos = jnp.asarray(kv_offset) + ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bsgrh,btgh->bsgrt", qg.astype(jnp.float32),
                       kcf) * scale
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        mask &= (kv_pos < skv + jnp.asarray(kv_offset))[None, :]  # pad chunk
        s = jnp.where(mask[None, :, None, None, :], s, neg)
        if kv_valid_len is not None:
            vl = jnp.asarray(kv_valid_len)
            cpos = ci * chunk + jnp.arange(chunk)
            if vl.ndim == 2:
                # [B, Sq]: per-query valid length (multi-token speculative
                # decode — query j may read cache written by query j-1)
                vmask = cpos[None, None, :] < vl[:, :, None]  # [B, Sq, chunk]
                s = jnp.where(vmask[:, :, None, None, :], s, neg)
            else:
                vl = vl[:, None] if vl.ndim == 1 else vl.reshape(1, 1)
                vmask = cpos[None, :] < vl  # [B, chunk]
                s = jnp.where(vmask[:, None, None, None, :], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bsgrt,btgh->bsgrh", p, vcf)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kv, rep), neg, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, rep), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash-decode via shard_map: sequence-sharded KV cache over the model axis
# ---------------------------------------------------------------------------

def flash_decode_shardmap(q, cache, pos, plan, *, chunk=1024):
    """Decode attention with the KV cache sharded along SEQUENCE over the
    model axis (§Perf B4, flash-decoding style).

    Under plain pjit the hd-/kv-sharded cache forces a per-chunk all-gather
    of KV into the score einsum (measured: 80 GiB/step on qwen2-72b
    decode_32k). Here each model shard owns S/mp cache positions, updates
    its local slice if the write position falls inside it, runs the local
    online-softmax, and the partial (m, l, acc) merge is ONE tiny all-gather
    per layer.

    q: [B, 1, H, hd]; cache: (k, v) or (k, v, ks, vs) with S-dim sharded
    over plan.model; pos: scalar next-token position.
    Returns (out [B, 1, H, hd], new_cache).
    """
    mesh = plan.mesh
    model_ax = plan.model
    batch_spec = plan.batch if len(plan.batch) > 1 else plan.batch[0]
    int8 = len(cache) == 4
    b, _, h, hd = q.shape
    kv = cache[0].shape[2]
    rep = h // kv

    qspec = P(batch_spec, None, None, None)
    cspec = P(batch_spec, model_ax, None, None)

    def body(q_, pos_, *cache_):
        idx = jax.lax.axis_index(model_ax)
        ck = cache_[0]
        s_loc = ck.shape[1]
        start = idx * s_loc
        # -- local cache write (new token k/v precomputed into q_'s tail? no:
        # the caller writes k/v before sharding; here cache is already
        # updated. This path only READS.)
        qg = q_.reshape(q_.shape[0], 1, kv, rep, hd).astype(jnp.float32)
        scale = hd ** -0.5
        local_pos = start + jnp.arange(s_loc)
        valid = local_pos <= pos_  # causal/validity vs global position

        def attend(kcf, vcf, vmask):
            s = jnp.einsum("bsgrh,btgh->bsgrt", qg, kcf) * scale
            s = jnp.where(vmask[None, None, None, None, :], s,
                          jnp.finfo(jnp.float32).min)
            m = jnp.max(s, axis=-1)
            p = jnp.exp(s - m[..., None])
            l = jnp.sum(p, axis=-1)
            acc = jnp.einsum("bsgrt,btgh->bsgrh", p, vcf)
            return m, l, acc

        kcf = ck.astype(jnp.float32)
        vcf = cache_[1].astype(jnp.float32)
        if int8:
            kcf = kcf * cache_[2]
            vcf = vcf * cache_[3]
        m, l, acc = attend(kcf, vcf, valid)
        # merge partials across the model axis (flash combine)
        mm = jax.lax.all_gather(m, model_ax)          # [mp, ...]
        ll = jax.lax.all_gather(l, model_ax)
        aa = jax.lax.all_gather(acc, model_ax)
        m_glob = jnp.max(mm, axis=0)
        corr = jnp.exp(mm - m_glob[None])
        l_glob = jnp.sum(ll * corr, axis=0)
        acc_glob = jnp.sum(aa * corr[..., None], axis=0)
        out = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.reshape(q_.shape).astype(q_.dtype)

    in_specs = (qspec, P()) + (cspec,) * len(cache)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=qspec, check_vma=False)
    return fn(q, jnp.asarray(pos), *cache)


# ---------------------------------------------------------------------------
# attention + MLP blocks (used by dense / hybrid / vlm / audio stacks)
# ---------------------------------------------------------------------------

def attention_init(key, cfg, *, d_model=None, cross=False, dtype=jnp.float32) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.head_dim
    keys = jax.random.split(key, 4)
    bias = getattr(cfg, "qkv_bias", False)
    return {
        "wq": dense_init(keys[0], d, cfg.n_heads * hd, bias=bias, dtype=dtype),
        "wk": dense_init(keys[1], d, cfg.n_kv_heads * hd, bias=bias, dtype=dtype),
        "wv": dense_init(keys[2], d, cfg.n_kv_heads * hd, bias=bias, dtype=dtype),
        "wo": dense_init(keys[3], cfg.n_heads * hd, d, dtype=dtype),
    }


def attention_apply(
    p: Params, x: jax.Array, cfg, *,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_pos: jax.Array | int = 0,
    xattn_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    positions: Optional[jax.Array] = None,
    window: Optional[int] = None,
    causal: bool = True,
    use_rope: bool = True,
    quant: Optional[dict] = None,
    page_table: Optional[jax.Array] = None,
):
    """Returns (out, new_kv_cache). Handles train/prefill/decode/cross.

    With ``page_table`` ([B, nb] int32) the cache leaves are block-pool
    shaped [num_blocks, block_size, ...]: writes scatter through the table
    and reads gather the slot's blocks into a contiguous [B, nb*bs] view
    (see kvcache.paged_gather/paged_scatter for the exactness argument).
    """
    b, s, d = x.shape
    hd = cfg.head_dim
    # per-slot decode (continuous batching): cache_pos is a [B] vector and
    # s == 1; each slot reads/writes its own position.
    per_slot = getattr(jnp.asarray(cache_pos), "ndim", 0) == 1
    tbl = make_table(x, quant)
    q = lut_dense(p["wq"], x, quant, tbl).reshape(b, s, cfg.n_heads, hd)
    if xattn_kv is None:
        k = lut_dense(p["wk"], x, quant, tbl).reshape(b, s, cfg.n_kv_heads, hd)
        v = lut_dense(p["wv"], x, quant, tbl).reshape(b, s, cfg.n_kv_heads, hd)
    else:
        k, v = xattn_kv  # precomputed cross-attention KV (encoder/image)

    if positions is None:
        if per_slot:
            positions = jnp.asarray(cache_pos)[:, None] + jnp.arange(s)  # [B,S]
        else:
            positions = jnp.asarray(cache_pos) + jnp.arange(s)
    if use_rope and xattn_kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    # ---- block-paged cache (pool leaves + page table): one branch covers
    # per-slot decode (cache_pos [B], s == 1) and chunked prefill at a
    # scalar offset — scatter the fresh k/v through the table, gather the
    # slot's logical view, then run the exact chunked_attention call the
    # matching dense branch runs (bit-exact: see kvcache paged helpers).
    if page_table is not None and kv_cache is not None and xattn_kv is None:
        if window is not None:
            raise NotImplementedError(
                "paged KV cache does not support sliding-window attention "
                "(rolling caches have their own fixed-size layout)")
        cp = jnp.asarray(cache_pos)
        base = cp if per_slot else jnp.broadcast_to(cp, (b,))
        pos2d = base[:, None] + jnp.arange(s)[None, :]  # [B, S] global write
        if len(kv_cache) == 4:  # int8 pool: codes + per-(pos, head) scales
            kq, ks_new = _quantize_kv_slice(k)
            vq, vs_new = _quantize_kv_slice(v)
            new_cache = tuple(
                kvcache.paged_scatter(leaf, vals, page_table, pos2d)
                for leaf, vals in zip(kv_cache, (kq, vq, ks_new, vs_new)))
            kg, vg, ksg, vsg = (kvcache.paged_gather(leaf, page_table)
                                for leaf in new_cache)
        else:
            new_cache = tuple(
                kvcache.paged_scatter(leaf, vals, page_table, pos2d)
                for leaf, vals in zip(kv_cache, (k, v)))
            kg, vg = (kvcache.paged_gather(leaf, page_table)
                      for leaf in new_cache)
            ksg = vsg = None
        if per_slot:
            if ksg is None:
                kg, vg = kg.astype(q.dtype), vg.astype(q.dtype)
            # s > 1 is the speculative decode burst: query j of slot b may
            # read every position up to its own write, so the valid length
            # is per-(slot, query) [B, S]. paged_scatter routes any
            # out-of-range pos2d through the null block, so slots near
            # max_seq stay safe.
            vlen = base + 1 if s == 1 else pos2d + 1
            out = chunked_attention(
                q, kg, vg, k_scale=ksg, v_scale=vsg,
                q_offset=0, causal=False, kv_valid_len=vlen,
                chunk=getattr(cfg, "attn_chunk", 1024))
        else:
            out = chunked_attention(
                q, kg, vg, k_scale=ksg, v_scale=vsg,
                q_offset=cp, causal=causal, kv_valid_len=cp + s,
                chunk=getattr(cfg, "attn_chunk", 1024))
        out = out.reshape(b, s, cfg.n_heads * hd)
        return lut_dense(p["wo"], out, quant), new_cache

    if per_slot and kv_cache is not None and xattn_kv is None:
        bi = jnp.arange(b)
        cp = jnp.asarray(cache_pos)
        # s == 1 keeps the exact single-token decode write; s > 1 is the
        # speculative burst: scatter all s fresh positions (mode="drop"
        # silently skips writes past max_seq — those queries are masked off
        # by the engine's budget logic) and give each query its own valid
        # length so query j sees positions <= cp + j.
        pos2d = cp[:, None] + jnp.arange(s)[None, :]  # [B, S]
        vlen = cp + 1 if s == 1 else pos2d + 1
        if len(kv_cache) == 4:  # int8 KV cache: quantize the new token slice
            ck, cv, cks, cvs = kv_cache
            kq, ks_new = _quantize_kv_slice(k)
            vq, vs_new = _quantize_kv_slice(v)
            if s == 1:
                ck = ck.at[bi, cp].set(kq[:, 0])
                cv = cv.at[bi, cp].set(vq[:, 0])
                cks = cks.at[bi, cp].set(ks_new[:, 0])
                cvs = cvs.at[bi, cp].set(vs_new[:, 0])
            else:
                bi2 = bi[:, None]
                ck = ck.at[bi2, pos2d].set(kq, mode="drop")
                cv = cv.at[bi2, pos2d].set(vq, mode="drop")
                cks = cks.at[bi2, pos2d].set(ks_new, mode="drop")
                cvs = cvs.at[bi2, pos2d].set(vs_new, mode="drop")
            out = chunked_attention(
                q, ck, cv, k_scale=cks, v_scale=cvs,
                q_offset=0, causal=False, kv_valid_len=vlen,
                chunk=getattr(cfg, "attn_chunk", 1024))
            out = out.reshape(b, s, cfg.n_heads * hd)
            return lut_dense(p["wo"], out, quant), (ck, cv, cks, cvs)
        ck, cv = kv_cache
        if s == 1:
            ck = ck.at[bi, cp].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bi, cp].set(v[:, 0].astype(cv.dtype))
        else:
            ck = ck.at[bi[:, None], pos2d].set(k.astype(ck.dtype), mode="drop")
            cv = cv.at[bi[:, None], pos2d].set(v.astype(cv.dtype), mode="drop")
        out = chunked_attention(
            q, ck.astype(q.dtype), cv.astype(q.dtype),
            q_offset=0, causal=False,
            kv_valid_len=vlen,
            chunk=getattr(cfg, "attn_chunk", 1024))
        out = out.reshape(b, s, cfg.n_heads * hd)
        return lut_dense(p["wo"], out, quant), (ck, cv)

    q_off = jnp.asarray(cache_pos)
    plan = current_plan()
    kv_valid = None
    k_scale = v_scale = None

    # ---- prefill fast path: attend over the fresh k/v (never read the
    # possibly-sequence-sharded cache back); cache update is output-only.
    if (kv_cache is not None and xattn_kv is None and s > 1
            and isinstance(cache_pos, int) and cache_pos == 0):
        if len(kv_cache) == 4:
            ck, cv, cks, cvs = kv_cache
            kq, ks_new = _quantize_kv_slice(k)
            vq, vs_new = _quantize_kv_slice(v)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, kq, 0, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, vq, 0, 1)
            cks = jax.lax.dynamic_update_slice_in_dim(cks, ks_new, 0, 1)
            cvs = jax.lax.dynamic_update_slice_in_dim(cvs, vs_new, 0, 1)
            new_cache = (ck, cv, cks, cvs)
        else:
            ck, cv = kv_cache
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), 0, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), 0, 1)
            new_cache = (ck, cv)
        # §Perf P2: sequence-parallel prefill attention. Without this, archs
        # whose head count doesn't divide the model axis (llama3.2-3b: 24
        # heads / 16) make XLA shard the hd CONTRACTION dim — an all-reduce
        # of the full score tensor per chunk (measured 672 GiB/step).
        # Sharding q's sequence over model instead costs one KV all-gather
        # per layer (~0.5 GiB) and keeps scores collective-free.
        if plan is not None:
            sizes = dict(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
            mp_sz = sizes.get(plan.model, 1)
            bspec = plan.batch if len(plan.batch) > 1 else plan.batch[0]
            # only when the head count can't shard cleanly — divisible-head
            # archs already get collective-free head-parallel attention
            if (mp_sz > 1 and s % mp_sz == 0
                    and cfg.n_heads % mp_sz != 0):
                q = jax.lax.with_sharding_constraint(
                    q, NamedSharding(plan.mesh, P(bspec, plan.model, None, None)))
                k = jax.lax.with_sharding_constraint(
                    k, NamedSharding(plan.mesh, P(bspec, None, None, None)))
                v = jax.lax.with_sharding_constraint(
                    v, NamedSharding(plan.mesh, P(bspec, None, None, None)))
        out = chunked_attention(q, k, v, q_offset=0, causal=causal,
                                window=window,
                                chunk=getattr(cfg, "attn_chunk", 1024))
        out = out.reshape(b, s, cfg.n_heads * hd)
        return lut_dense(p["wo"], out, quant), new_cache

    # ---- flash-decode (§Perf B4): sequence-sharded cache over the model
    # axis, local online-softmax per shard, one (m,l,acc) merge per layer.
    if _flash_decode_ok(plan, kv_cache, b, s, window, per_slot) \
            and xattn_kv is None:
        if len(kv_cache) == 4:
            ck, cv, cks, cvs = kv_cache
            kq, ks_new = _quantize_kv_slice(k)
            vq, vs_new = _quantize_kv_slice(v)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, kq, q_off, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, vq, q_off, 1)
            cks = jax.lax.dynamic_update_slice_in_dim(cks, ks_new, q_off, 1)
            cvs = jax.lax.dynamic_update_slice_in_dim(cvs, vs_new, q_off, 1)
            new_cache = (ck, cv, cks, cvs)
        else:
            ck, cv = kv_cache
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), q_off, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), q_off, 1)
            new_cache = (ck, cv)
        out = flash_decode_shardmap(q, new_cache, q_off, plan)
        out = out.reshape(b, s, cfg.n_heads * hd)
        return lut_dense(p["wo"], out, quant), new_cache

    if kv_cache is not None and xattn_kv is None and len(kv_cache) == 4:
        # int8 KV cache (paper §5 direction): quantize the new slice with
        # per-(position, head) absmax scales, dequantize per chunk in-loop.
        ck, cv, cks, cvs = kv_cache
        ks_new = jnp.maximum(jnp.max(jnp.abs(k.astype(jnp.float32)), -1,
                                     keepdims=True), 1e-8) / 127.0
        vs_new = jnp.maximum(jnp.max(jnp.abs(v.astype(jnp.float32)), -1,
                                     keepdims=True), 1e-8) / 127.0
        kq = jnp.clip(jnp.round(k.astype(jnp.float32) / ks_new), -127, 127
                      ).astype(jnp.int8)
        vq = jnp.clip(jnp.round(v.astype(jnp.float32) / vs_new), -127, 127
                      ).astype(jnp.int8)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kq, q_off, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vq, q_off, 1)
        cks = jax.lax.dynamic_update_slice_in_dim(cks, ks_new, q_off, 1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cvs, vs_new, q_off, 1)
        out = chunked_attention(
            q, ck, cv, k_scale=cks, v_scale=cvs,
            q_offset=q_off, causal=causal, kv_valid_len=q_off + s,
            window=window, chunk=getattr(cfg, "attn_chunk", 1024))
        out = out.reshape(b, s, cfg.n_heads * hd)
        return lut_dense(p["wo"], out, quant), (ck, cv, cks, cvs)
    if kv_cache is not None and xattn_kv is None:
        ck, cv = kv_cache
        s_max = ck.shape[1]
        if window is not None and s_max == window:
            # rolling sliding-window cache: slot = pos mod window
            slot = (q_off + jnp.arange(s)) % window
            ck = ck.at[:, slot].set(k.astype(ck.dtype))
            cv = cv.at[:, slot].set(v.astype(cv.dtype))
            # (window caches are small; _attend_rolling casts in-einsum)
            # rolling cache: score by *stored global position* per slot
            stored_pos = _rolling_positions(q_off + s, window)
            out = _attend_rolling(q, ck, cv, q_pos=q_off + jnp.arange(s),
                                  stored_pos=stored_pos, window=window)
            out = out.reshape(b, s, cfg.n_heads * hd)
            return lut_dense(p["wo"], out, quant), (ck, cv)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), q_off, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), q_off, 1)
        k, v = ck, cv
        kv_cache = (ck, cv)
        kv_valid = q_off + s
    # §Perf D1: pass k/v in cache dtype — converting the full cache to the
    # activation dtype here materialized an f32 cache copy per layer (and
    # full-cache convert round-trips in the scanned DUS); the chunk body
    # upcasts chunk-sized slices inside its einsums instead.
    out = chunked_attention(
        q, k, v,
        q_offset=q_off, causal=causal and xattn_kv is None,
        window=window, kv_valid_len=kv_valid,
        chunk=getattr(cfg, "attn_chunk", 1024))
    out = out.reshape(b, s, cfg.n_heads * hd)
    return lut_dense(p["wo"], out, quant), kv_cache


def _rolling_positions(next_pos, window):
    """Global position stored in each rolling-cache slot given next write pos."""
    slots = jnp.arange(window)
    # last write to slot i was at the largest p < next_pos with p % window == i
    base = (next_pos - 1 - slots) // window
    return slots + base * window  # may be negative => never written


def _attend_rolling(q, ck, cv, *, q_pos, stored_pos, window):
    """Attention over a rolling cache with per-slot global positions."""
    b, s, h, hd = q.shape
    kv = ck.shape[2]
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, hd).astype(jnp.float32)
    scale = hd ** -0.5
    sres = jnp.einsum("bsgrh,btgh->bsgrt", qg, ck.astype(jnp.float32)) * scale
    valid = (stored_pos[None, :] >= 0) & (stored_pos[None, :] <= q_pos[:, None])
    valid &= q_pos[:, None] - stored_pos[None, :] < window
    sres = jnp.where(valid[None, :, None, None, :], sres,
                     jnp.finfo(jnp.float32).min)
    pr = jax.nn.softmax(sres, axis=-1)
    out = jnp.einsum("bsgrt,btgh->bsgrh", pr, cv.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
         "down": dense_init(ks[2], d_ff, d_model, dtype=dtype)}
    if gated:
        p["gate"] = dense_init(ks[0], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p: Params, x: jax.Array, quant: Optional[dict] = None) -> jax.Array:
    tbl = make_table(x, quant)
    if "gate" in p:  # SwiGLU
        g = lut_dense(p["gate"], x, quant, tbl)
        u = lut_dense(p["up"], x, quant, tbl)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:  # GELU (whisper-style)
        h = jax.nn.gelu(lut_dense(p["up"], x, quant, tbl).astype(jnp.float32)
                        ).astype(x.dtype)
    return lut_dense(p["down"], h, quant)
