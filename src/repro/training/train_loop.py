"""Train-step factory: QAT forward (the paper's technique in training),
microbatch gradient accumulation, int8 gradient compression hook, pjit
shardings.

``make_train_step(cfg, opt, ...)`` returns a pure
``(state, batch) -> (state, metrics)`` suitable for jax.jit with the
shardings produced by ``train_shardings``.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.distributed import compression
from repro.distributed.sharding import AxisPlan, named_sharding_tree
from repro.models import api
from repro.models.transformer import lm_loss
from repro.training.optimizer import Optimizer, global_norm

TrainState = Dict[str, Any]  # {"params", "opt", "step"}


def make_loss_fn(cfg: ArchConfig, lb_coef=0.01, z_coef=0.001):
    def loss_fn(params, batch):
        logits, _, aux = api.forward(params, batch, cfg)
        loss = lm_loss(logits, batch["labels"])
        metrics = {"lm_loss": loss}
        if "lb_loss" in aux:
            loss = loss + lb_coef * aux["lb_loss"] + z_coef * aux["router_z_loss"]
            metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics
    return loss_fn


def make_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    *,
    microbatches: int = 1,
    grad_compression: Optional[str] = None,  # None | "int8"
    qat: bool = True,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Build the train step. QAT fake-quant is applied when the config has a
    quant block (paper §5: the mpGEMM technique on the training forward)."""
    if qat and cfg.quant:
        cfg = cfg.with_quant(qat=True)
    loss_fn = make_loss_fn(cfg)

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def accumulate(params, batch):
        if microbatches == 1:
            return single(params, batch)
        def resh(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(resh, batch)

        def body(carry, mbatch):
            g_acc, m_acc = carry
            g, m = single(params, mbatch)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            m_acc = jax.tree.map(jnp.add, m_acc, m)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": 0.0, "lm_loss": 0.0}
        g1, m1 = single(params, jax.tree.map(lambda x: x[0], mb))
        m0 = jax.tree.map(lambda x: jnp.zeros_like(x), m1)
        (g, m), _ = jax.lax.scan(body, (g0, m0),
                                 jax.tree.map(lambda x: x[1:], mb))
        g = jax.tree.map(jnp.add, g, g1)
        m = jax.tree.map(jnp.add, m, m1)
        inv = 1.0 / microbatches
        return (jax.tree.map(lambda x: x * inv, g),
                jax.tree.map(lambda x: x * inv, m))

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params = state["params"]
        grads, metrics = accumulate(params, batch)
        if grad_compression == "int8":
            # error-feedback residual lives in state["ef"]
            grads, new_ef = compression.compress_decompress_tree(
                grads, state.get("ef"))
        else:
            new_ef = state.get("ef")
        new_params, new_opt = opt.update(grads, state["opt"], params)
        metrics["grad_norm"] = global_norm(grads)
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if new_ef is not None:
            new_state["ef"] = new_ef
        return new_state, metrics

    return step


def init_train_state(key, cfg: ArchConfig, opt: Optimizer,
                     grad_compression: Optional[str] = None) -> TrainState:
    params = api.init_params(key, cfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if grad_compression == "int8":
        state["ef"] = compression.init_error_feedback(params)
    return state


def train_shardings(state: TrainState, plan: AxisPlan):
    """NamedShardings for the train state: params by rule table; optimizer
    state mirrors its param's sharding (factored vectors follow the rows)."""
    p_sh = named_sharding_tree(state["params"], plan)

    def mirror(path_sh, st):
        # opt m/v (or int8 {"q","s"}) follow params where shapes match
        return jax.tree.map(
            lambda x: path_sh if getattr(x, "shape", None) == getattr(
                path_sh, "shape", None) else None, st)

    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(plan.mesh, P())

    def opt_sharding(params_sh, opt_state):
        flat_p, tdef = jax.tree_util.tree_flatten(params_sh)

        def leaf_sharding(sh, leaf):
            if isinstance(leaf, dict):
                return {k: repl for k in leaf}
            return sh if leaf.ndim == len(sh.spec) else repl

        out = {}
        for key, sub in opt_state.items():
            if key == "step":
                out[key] = repl
                continue
            flat_s = tdef.flatten_up_to(sub)
            out[key] = tdef.unflatten(
                [leaf_sharding(s, l) for s, l in zip(flat_p, flat_s)])
        return out

    sh = {"params": p_sh, "opt": opt_sharding(p_sh, state["opt"]),
          "step": repl}
    if "ef" in state:
        sh["ef"] = p_sh
    return sh
