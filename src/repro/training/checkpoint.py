"""Sharded, atomic, async-capable checkpointing with restart management.

Layout: ``<dir>/step_<n>/`` holding one ``.npz`` per host-shard (here: one)
plus a ``MANIFEST.json`` (tree structure, shapes, dtypes, step, config
fingerprint). Writes go to ``step_<n>.tmp`` then ``os.rename`` — a crashed
writer never corrupts the latest checkpoint (fault-tolerance invariant).

``RestartManager`` implements the recovery policy: resume from the newest
*complete* checkpoint (manifest present), garbage-collect old ones, and
optionally write asynchronously on a background thread (double-buffered so
the training step never blocks on disk).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "MANIFEST.json"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None):
    """Atomic checkpoint write (synchronous)."""
    t_start = time.monotonic()
    names, leaves, _ = _flatten_with_names(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    def to_np(x):
        a = np.asarray(x)
        # np.savez cannot round-trip ml_dtypes (bfloat16/fp8): store as f32
        # (lossless upcast); restore() casts back to the target leaf dtype.
        if a.dtype.kind not in "biufc":
            a = a.astype(np.float32)
        return a

    arrays = {f"a{i}": to_np(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    manifest = {
        "step": int(step),
        "names": names,
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        # wall-clock is METADATA ONLY (when was this checkpoint taken);
        # never use it for interval math — durations below are monotonic
        "time": time.time(),
        "write_seconds": None,  # filled in below
        "extra": extra or {},
    }
    manifest["write_seconds"] = time.monotonic() - t_start
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``; returns (tree, step)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    leaves = [data[f"a{i}"] for i in range(len(manifest["names"]))]
    names, cur_leaves, treedef = _flatten_with_names(tree_like)
    if names != manifest["names"]:
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(names) ^ set(manifest['names'])}")
    restored = [jnp.asarray(x, dtype=getattr(c, "dtype", None))
                for x, c in zip(leaves, cur_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored), step


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
                steps.append(int(name[5:]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


class RestartManager:
    """Checkpoint/restart policy: periodic async saves, bounded retention,
    resume-from-latest-complete."""

    def __init__(self, ckpt_dir: str, *, every: int = 100, keep: int = 3,
                 async_write: bool = True):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, tree, extra=None, force=False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device
        if self.async_write:
            self.wait()  # double-buffer: at most one write in flight
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree, extra),
                daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree, extra)
        return True

    def _save_and_gc(self, step, tree, extra):
        save(self.dir, step, tree, extra=extra)
        for s in list_steps(self.dir)[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_or_none(self, tree_like):
        step = latest_step(self.dir)
        if step is None:
            return None, 0
        tree, step = restore(self.dir, tree_like, step)
        return tree, step
