"""Fault-tolerance policies: failure detection, straggler mitigation, and the
restart/elastic-downsize decision loop.

On real multi-host TPU deployments these hooks attach to the launcher
(heartbeats over the coordination service); in this CPU container the same
state machine is driven by simulated events — tests exercise the policy
logic, the dry-run proves the re-meshed programs compile.

Policies implemented:
  * heartbeat-timeout failure detection (per-host deadline),
  * straggler mitigation: per-step duration EWMA; hosts slower than
    ``straggler_factor``× the median for ``patience`` consecutive steps are
    marked for replacement by a hot spare (or trigger elastic downsize),
  * restart decision: RESUME (same mesh) when spares cover failures,
    ELASTIC_DOWNSIZE (shrink the data axis, rescale microbatching —
    distributed/elastic.py) otherwise.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional


class Action(enum.Enum):
    CONTINUE = "continue"
    REPLACE_WITH_SPARE = "replace_with_spare"
    RESUME_SAME_MESH = "resume_same_mesh"
    ELASTIC_DOWNSIZE = "elastic_downsize"


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float   # monotonic seconds (never wall-clock)
    step_ewma: float = 0.0
    slow_streak: int = 0
    alive: bool = True


class FaultToleranceManager:
    """All heartbeat interval math runs on ``time.monotonic()``: a wall
    clock (``time.time``) can jump backward or forward under NTP slew or
    manual adjustment, and a forward jump larger than ``heartbeat_timeout``
    fires spurious timeouts on every healthy host at once. Callers passing
    explicit ``now`` values (tests, simulated drivers) must use one
    consistent time base across calls — the units are seconds either way."""

    def __init__(self, n_hosts: int, *, n_spares: int = 0,
                 heartbeat_timeout: float = 60.0,
                 straggler_factor: float = 1.5, patience: int = 5):
        now = time.monotonic()
        self.hosts = {i: HostState(i, now) for i in range(n_hosts)}
        self.n_spares = n_spares
        self.timeout = heartbeat_timeout
        self.factor = straggler_factor
        self.patience = patience

    # -- event ingestion ------------------------------------------------------
    def heartbeat(self, host_id: int, step_duration: Optional[float] = None,
                  now: Optional[float] = None):
        h = self.hosts[host_id]
        h.last_heartbeat = now if now is not None else time.monotonic()
        if step_duration is not None:
            h.step_ewma = (0.7 * h.step_ewma + 0.3 * step_duration
                           if h.step_ewma else step_duration)

    def mark_failed(self, host_id: int):
        self.hosts[host_id].alive = False

    # -- policy ---------------------------------------------------------------
    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.monotonic()
        return [h.host_id for h in self.hosts.values()
                if not h.alive or now - h.last_heartbeat > self.timeout]

    def stragglers(self) -> List[int]:
        ew = sorted(h.step_ewma for h in self.hosts.values() if h.step_ewma > 0)
        if not ew:
            return []
        median = ew[len(ew) // 2]
        out = []
        for h in self.hosts.values():
            if h.step_ewma > self.factor * median:
                h.slow_streak += 1
                if h.slow_streak >= self.patience:
                    out.append(h.host_id)
            else:
                h.slow_streak = 0
        return out

    def decide(self, now: Optional[float] = None) -> Action:
        dead = set(self.dead_hosts(now))
        slow = set(self.stragglers())
        impaired = dead | slow
        if not impaired:
            return Action.CONTINUE
        if len(impaired) <= self.n_spares:
            self.n_spares -= len(impaired)
            for i in impaired:
                self.hosts[i].alive = False
            return Action.REPLACE_WITH_SPARE
        if dead:
            return Action.ELASTIC_DOWNSIZE
        return Action.RESUME_SAME_MESH
