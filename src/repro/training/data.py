"""Token data pipeline: deterministic synthetic streams + file-backed packed
corpora, with host-side prefetch and checkpointable iterator state.

Determinism & fault tolerance: the stream is a pure function of
(seed, step), so after restart the pipeline resumes exactly at the restored
step — no data skipped/duplicated. This is the property that makes
checkpoint/restart bitwise-reproducible.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Markov-ish synthetic token stream (fast, deterministic, nontrivial):
    mixtures of ngram-cycles so a real model can actually reduce loss."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                 n_patterns: int = 64, pattern_len: int = 16):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.patterns = rng.integers(
            0, vocab_size, size=(n_patterns, pattern_len), dtype=np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        pid = rng.integers(0, len(self.patterns), size=self.batch)
        off = rng.integers(0, self.patterns.shape[1], size=self.batch)
        idx = (np.arange(self.seq + 1)[None, :] + off[:, None]) % self.patterns.shape[1]
        toks = self.patterns[pid[:, None], idx]
        noise = rng.random(toks.shape) < 0.05
        toks = np.where(noise, rng.integers(0, self.vocab, size=toks.shape), toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedCorpus:
    """File-backed token corpus (flat .npy of int32 token ids), packed into
    fixed-length rows; step-indexed for deterministic restart."""

    def __init__(self, path: str, batch: int, seq_len: int, seed: int = 0):
        self.tokens = np.load(path, mmap_mode="r")
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.n_rows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        rows = rng.integers(0, self.n_rows, size=self.batch)
        starts = rows * self.seq
        tok = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        return {"tokens": tok[:, :-1].astype(np.int32),
                "labels": tok[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch of ``batch_at(step)`` with bounded depth."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch_at(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self):
        s, b = self.q.get()
        self.step = s + 1
        return b

    def close(self):
        self._stop.set()
