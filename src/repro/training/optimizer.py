"""Optimizers built from scratch (no optax in this environment).

  * ``adamw``      — the default.
  * ``adafactor``  — factored second moments, O(rows+cols) state; what lets
                     the 1T-param kimi-k2 config fit 16GB/chip HBM.
  * ``momentum``   — SGD + momentum (baseline).
  * 8-bit state quantization (``state_bits=8``): AdamW m/v stored INT8 with
    per-tensor absmax scales (block-wise for large tensors) — a
    distributed-memory trick in the same spirit as the paper's table
    quantization, and it reuses the same absmax-int8 machinery.

API: ``opt = make_optimizer(name, lr=..., **kw)``;
``state = opt.init(params)``; ``params, state = opt.update(grads, state,
params)``. Everything is a pure pytree function, pjit-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


# ---------------------------------------------------------------------------
# int8 state quantization (blockwise absmax)
# ---------------------------------------------------------------------------

_BLOCK = 2048


def _q8(x):
    """float -> (int8 codes, f32 scales) with per-block absmax."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, _BLOCK)
    s = jnp.maximum(jnp.max(jnp.abs(blk), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blk / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dq8(q, s, shape):
    flat = (q.astype(jnp.float32) * s).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


# ---------------------------------------------------------------------------
# AdamW (optionally with int8 m/v)
# ---------------------------------------------------------------------------

def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
          state_bits: Optional[int] = None, grad_clip: Optional[float] = 1.0):
    use_q8 = state_bits == 8

    def init(params):
        def zeros_like_state(p):
            if use_q8 and p.size >= _BLOCK:
                q, s = _q8(jnp.zeros_like(p, jnp.float32))
                return {"q": q, "s": s}
            return jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros_like_state, params),
            "v": jax.tree.map(zeros_like_state, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip is not None:
            gnorm = global_norm(grads)
            factor = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * factor, grads)
        t = step.astype(jnp.float32)
        lr_t = lr(step) if callable(lr) else lr
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = _dq8(m["q"], m["s"], p.shape) if isinstance(m, dict) else m
            vf = _dq8(v["q"], v["s"], p.shape) if isinstance(v, dict) else v
            mf = b1 * mf + (1 - b1) * gf
            vf = b2 * vf + (1 - b2) * jnp.square(gf)
            upd_ = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
            if weight_decay:
                upd_ = upd_ + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * upd_).astype(p.dtype)
            if isinstance(m, dict):
                qm, sm = _q8(mf)
                qv, sv = _q8(vf)
                return newp, {"q": qm, "s": sm}, {"q": qv, "s": sv}
            return newp, mf, vf

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; for the 1T-param configs)
# ---------------------------------------------------------------------------

def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_threshold=1.0,
              min_dim_size_to_factor=128, weight_decay=0.0):
    def _factored(shape):
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor \
            and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def state_for(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(state_for, params,
                                  is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr_t = lr(step) if callable(lr) else lr
        beta2 = 1.0 - t ** (-decay)

        def upd(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_s = {"vr": vr, "vc": vc}
            else:
                vhat = beta2 * s["v"] + (1 - beta2) * g2
                new_s = {"v": vhat}
            u = gf / jnp.sqrt(vhat + eps)
            # update clipping (Adafactor's RMS rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        return (tdef.unflatten([o[0] for o in out]),
                {"step": step, "v": tdef.unflatten([o[1] for o in out])})

    return Optimizer(init, update)


def momentum(lr=1e-2, beta=0.9, weight_decay=0.0):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        lr_t = lr(state["step"] + 1) if callable(lr) else lr
        def upd(p, g, m):
            mf = beta * m + g.astype(jnp.float32)
            u = mf + (weight_decay * p.astype(jnp.float32) if weight_decay else 0.0)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), mf
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (tdef.unflatten([o[0] for o in out]),
                {"step": state["step"] + 1,
                 "m": tdef.unflatten([o[1] for o in out])})

    return Optimizer(init, update)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adamw8bit":
        return adamw(state_bits=8, **kw)
    if name == "adafactor":
        return adafactor(**kw)
    if name == "momentum":
        return momentum(**kw)
    raise ValueError(f"unknown optimizer {name!r}")


def lr_schedule(base_lr: float, warmup: int, total: int):
    """Linear warmup + cosine decay, as a jittable fn of step."""
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return fn
