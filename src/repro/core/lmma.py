"""LMMA instruction descriptors + memory-size-based tile scheduler (§3.3).

The paper extends MMA to ``lmma.{M}{N}{K}.{A}{W}{Acc}{O}``.  On TPU the
"instruction" becomes a *kernel schedule contract*: an ``LMMADescriptor``
names the tile shape and operand dtypes, and ``schedule_tiles`` picks
BlockSpec block shapes for the Pallas kernels the way §3.3.2 prescribes —
**tiling by memory size, not by shape**, because the A-side (table bytes) and
W-side (packed code bytes) of an mpGEMM tile have wildly different densities.

The scheduler objective mirrors Roller's rTile logic: choose the largest
(bm, bn, bg) whose working set fits the VMEM budget, with bn elongated
(table-reuse, §3.2.2) and hardware-aligned lane dims (multiples of 128).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["LMMADescriptor", "TileSchedule", "schedule_tiles", "lmma_name",
           "fused_tile_bytes", "select_fusion"]

VMEM_BYTES = 64 * 1024 * 1024  # v5e VMEM ~128MB/2 cores -> 64MB usable/core
LANE = 128
SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class LMMADescriptor:
    """lmma.{M}{N}{K}.{A}{W}{Acc}{O} — operand shapes and dtypes."""

    m: int
    n: int
    k: int                      # contraction length (K_total)
    a_dtype: str = "bf16"       # fp16/bf16/fp8/int8 activations
    w_bits: int = 2             # INT1/2/4 weights (ternary -> 2 planes)
    acc_dtype: str = "f32"
    o_dtype: str = "bf16"
    k_group: int = 4
    table_bits: int = 8         # LUT_BIT after table quantization

    def name(self) -> str:
        return (f"lmma.m{self.m}n{self.n}k{self.k}."
                f"a{self.a_dtype}.w int{self.w_bits}".replace(" ", "") +
                f".acc{self.acc_dtype}.o{self.o_dtype}")


def lmma_name(desc: LMMADescriptor) -> str:
    return desc.name()


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    bm: int
    bn: int
    bg: int  # groups per K-block (K elements = bg * k_group)
    table_bytes: int
    weight_bytes: int
    acc_bytes: int
    vmem_bytes: int

    @property
    def bk(self) -> int:
        return self.bg  # alias; K elements per block = bg * k_group


_DTYPE_BYTES = {"fp16": 2, "bf16": 2, "f32": 4, "fp8": 1, "int8": 1, "int32": 4}


def _tile_bytes(bm, bn, bg, desc: LMMADescriptor) -> Tuple[int, int, int]:
    e = 1 << (desc.k_group - 1)
    planes = desc.w_bits if desc.w_bits > 0 else 2
    table = bm * bg * e * (desc.table_bits // 8 or 1)          # Eq. 7
    weights = bn * bg * planes * desc.k_group // 8              # Eq. 8 packed
    cw = bn * bg * e                                            # int8 CW expansion
    acc = bm * bn * _DTYPE_BYTES[desc.acc_dtype]
    return table, weights + cw, acc


def schedule_tiles(desc: LMMADescriptor,
                   vmem_budget: int = VMEM_BYTES,
                   elongate: bool = True) -> TileSchedule:
    """Pick (bm, bn, bg) by memory size (§3.3.2) with elongated N (§3.2.2)."""
    g_total = desc.k / desc.k_group
    best: Optional[TileSchedule] = None
    bm_cands = [m for m in (8, 16, 32, 64, 128, 256) if m <= max(desc.m, 8)]
    bn_cands = [n for n in (128, 256, 512, 1024, 2048) if n <= max(desc.n, LANE)]
    bg_cands = [g for g in (8, 16, 32, 64, 128, 256, 512) if g <= max(g_total, 8)]
    for bm in bm_cands:
        for bn in bn_cands:
            for bg in bg_cands:
                t, w, a = _tile_bytes(bm, bn, bg, desc)
                tot = 2 * (t + w) + a  # double-buffered inputs
                if tot > vmem_budget:
                    continue
                cand = TileSchedule(bm, bn, bg, t, w, a, tot)
                # score: MACs per byte moved (table reuse over bn — the
                # elongation pressure, §3.2.2), tie-broken toward larger bn.
                if best is None or _score(cand, desc, elongate) > _score(best, desc, elongate):
                    best = cand
    if best is None:
        t, w, a = _tile_bytes(8, LANE, 8, desc)
        best = TileSchedule(8, LANE, 8, t, w, a, 2 * (t + w) + a)
    return best


def fused_tile_bytes(bm: int, bn: int, bg: int, desc: LMMADescriptor) -> int:
    """Per-grid-step VMEM working set of the fused precompute→lookup kernel.

    Unlike the staged kernel (whose A-side input is the HBM-resident table
    block), the fused kernel streams the raw activation block and rebuilds
    the table in-VMEM, so its working set carries BOTH the activation block
    and the recomputed [bm, bg·E] table block (f32 entries plus the int8
    quantized copy), alongside the usual packed-weight / CW / accumulator
    terms.
    """
    e = 1 << (desc.k_group - 1)
    planes = desc.w_bits if desc.w_bits > 0 else 2
    a_blk = bm * bg * desc.k_group * _DTYPE_BYTES[desc.a_dtype]
    ent_f32 = bm * bg * e * 4                       # basis-contraction result
    tbl_q = bm * bg * e * (desc.table_bits // 8 or 1)
    weights = bn * bg * planes * desc.k_group // 8
    cw = bn * bg * e
    acc = bm * bn * _DTYPE_BYTES[desc.acc_dtype]
    return 2 * (a_blk + weights) + ent_f32 + tbl_q + cw + acc


def select_fusion(desc: LMMADescriptor,
                  ts: Optional[TileSchedule] = None,
                  vmem_budget: int = VMEM_BYTES) -> str:
    """§3.1.1 fusion decision: 'fused' iff the table block fits VMEM.

    The fused kernel never writes the [M, G·E] table to HBM, but pays an
    in-VMEM recompute per (N-tile, K-block) step; it is profitable exactly
    when its enlarged working set still fits the VMEM budget — which it does
    for every tile the memory-size scheduler emits, EXCEPT when callers pin
    oversized (bm, bg) by hand. Returns "fused" or "staged".
    """
    if ts is None:
        ts = schedule_tiles(desc)
    fusion = ("fused"
              if fused_tile_bytes(ts.bm, ts.bn, ts.bg, desc) <= vmem_budget
              else "staged")
    # trace-time dispatch profiling hook (no-op unless a recorder is active)
    from repro.obs import dispatch as dispatch_obs
    dispatch_obs.record("select_fusion", desc.name(), fusion, "auto",
                        "heuristic", (ts.bm, ts.bn, ts.bg))
    return fusion


def _score(ts: TileSchedule, desc: LMMADescriptor, elongate: bool) -> float:
    e = 1 << (desc.k_group - 1)
    g_total = desc.k / desc.k_group
    macs = ts.bm * ts.bn * ts.bg * e
    score = macs / (ts.table_bytes + ts.weight_bytes
                    + ts.acc_bytes / max(1, (g_total // ts.bg)))
    if elongate:
        score *= (1.0 + 0.1 * (ts.bn / 2048))
    return score
