"""Lookup-table precompute + symmetrization + table quantization (§3.1).

The half-table for a K-group of activations ``a_0..a_{K-1}`` stores, for every
entry ``e ∈ [0, 2^(K-1))``::

    T[e] = Σ_{i<K-1} a_i * (2*bit_i(e) - 1)  -  a_{K-1}

i.e. the MSB position is pinned to σ = -1 (entries with MSB=+1 are recovered
by oddness, Eq. 4-5).  Precompute is *split out as an independent operator*
(the paper's DFG transformation, §3.1.1) so callers can fuse it with the
preceding element-wise op and share one table across all N output channels.

Table quantization (§3.1.3) converts float entries to INT8 with a dynamic
scale, either per-table (``per_group``, the paper's hardware choice) or
per-activation-row (``per_row``, the TPU/XLA-friendly choice that lets the
whole lookup run as one int8 GEMM — see DESIGN.md §2).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["Table", "sign_basis", "precompute_table", "quantize_table", "table_entries"]


class Table(NamedTuple):
    """Precomputed (optionally quantized) lookup tables.

    values:  [M, G, E] float32 or int8, E = 2^(k_group-1)
    scale:   None (float tables) | [M, 1, 1] (per_row) | [M, G, 1] (per_group)
    rowsum:  [M] float32 — Σ_k a[m,k], used for the zero-point correction term
    k_group: group length K
    """

    values: jax.Array
    scale: Optional[jax.Array]
    rowsum: jax.Array
    k_group: int


@functools.lru_cache(maxsize=None)
def _sign_basis_np(k_group: int) -> np.ndarray:
    """[K, E] ±1 basis: column e holds (σ_0..σ_{K-1}) with σ_{K-1} = -1."""
    e = 1 << (k_group - 1)
    basis = np.empty((k_group, e), dtype=np.float32)
    ent = np.arange(e)
    for i in range(k_group - 1):
        basis[i] = 2.0 * ((ent >> i) & 1) - 1.0
    basis[k_group - 1] = -1.0
    return basis


def sign_basis(k_group: int) -> jax.Array:
    return jnp.asarray(_sign_basis_np(k_group))


def table_entries(a_groups: jax.Array, k_group: int) -> jax.Array:
    """[..., G, K] activations -> [..., G, E] half-table entries.

    One matmul against the ±1 basis; on TPU this runs on the MXU and is the
    natural fusion target after the preceding element-wise op.
    """
    return jnp.einsum(
        "...gk,ke->...ge", a_groups.astype(jnp.float32), sign_basis(k_group)
    )


def group_absmax(a_groups: jax.Array) -> jax.Array:
    """Closed-form max_e |T[e]| = Σ_i |a_i| per group (oddness ⇒ achievable).

    Using this identity (instead of materializing entries and reducing over
    E) lets the per-row scale be computed from A *before* the table exists —
    the kernel and the oracle share it bit-exactly.
    """
    return jnp.sum(jnp.abs(a_groups.astype(jnp.float32)), axis=-1)  # [..., G]


def precompute_table(
    a: jax.Array,
    k_group: int = 4,
    table_quant: Optional[str] = None,
) -> Table:
    """The independent precompute operator (DFG-transformed, §3.1.1).

    Args:
      a: activations [M, K_total], K_total divisible by k_group.
      table_quant: None | 'per_group' | 'per_row' — INT8 table quantization.
    """
    m, k_total = a.shape
    if k_total % k_group:
        raise ValueError(f"K_total={k_total} not divisible by k_group={k_group}")
    g = k_total // k_group
    af = a.astype(jnp.float32)
    rowsum = jnp.sum(af, axis=-1)
    a_groups = af.reshape(m, g, k_group)
    entries = table_entries(a_groups, k_group)
    if table_quant is None:
        return Table(entries, None, rowsum, k_group)
    absmax = group_absmax(a_groups)  # [M, G]
    return quantize_table(entries, rowsum, k_group, table_quant, absmax=absmax)


def quantize_table(
    entries: jax.Array, rowsum: jax.Array, k_group: int, mode: str,
    absmax: Optional[jax.Array] = None,
) -> Table:
    """INT8 table quantization (§3.1.3) with dynamic absmax scaling."""
    if absmax is None:
        absmax = jnp.max(jnp.abs(entries), axis=-1)  # [M, G]
    if mode == "per_group":
        absmax = absmax[..., None]  # [M,G,1]
    elif mode == "per_row":
        absmax = jnp.max(absmax, axis=-1)[:, None, None]  # [M,1,1]
    else:
        raise ValueError(f"unknown table_quant mode {mode!r}")
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(entries / scale), -127, 127).astype(jnp.int8)
    return Table(q, scale, rowsum, k_group)


def dequantize_table(t: Table) -> jax.Array:
    if t.scale is None:
        return t.values
    return t.values.astype(jnp.float32) * t.scale
