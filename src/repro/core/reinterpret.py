"""Weight reinterpretation (paper §3.1.2, Eq. 1-6).

The paper maps unsigned B-bit weight codes ``q ∈ {0..2^B-1}`` onto the
symmetric *odd* grid::

    q' = 2q - (2^B - 1)          (Eq. 2)   q' ∈ {-(2^B-1), ..., -1, +1, ..., 2^B-1}
    s' = s / 2
    z' = 2z + 1 - 2^B

so that ``s (q - z) == s' (q' - z')`` (Eq. 3) — i.e. the represented real
weight is unchanged, but the integer grid is now symmetric around zero.

Two consequences power the whole design:

1. **Exact bit-serial sign-plane decomposition.**  Writing
   ``q = Σ_b 2^b q_b`` with ``q_b ∈ {0,1}`` gives

       q' = Σ_b 2^b (2 q_b - 1) = Σ_b 2^b σ_b,      σ_b ∈ {-1, +1}

   so a B-bit reinterpreted weight is *exactly* a sum of B ±1 planes with
   power-of-two plane scales.  Every plane shares one lookup table.

2. **Table symmetrization** (Eq. 4-5): the per-group table of a ±1 plane is
   odd — ``LUT[w] = -LUT[~w]`` — so only ``2^(K-1)`` of ``2^K`` entries are
   stored.  Eq. 6 folds the MSB-conditional bit negation into the *offline*
   stored codes so no negation circuit / runtime bit-flip is needed.

Ternary (BitNet b1.58) codes ``t ∈ {-1,0,1}`` are not on the odd grid but
decompose into **two** ±1 planes with equal plane scales::

    t = (σ_a + σ_b) / 2,   σ_a = +1 iff t >= 0,   σ_b = +1 iff t > 0

which this module also provides (plane_scales = [1, 1], scale absorbs 1/2).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "reinterpret_scale_zero",
    "reinterpret_codes",
    "codes_to_sign_planes",
    "ternary_to_sign_planes",
    "plane_scales_for",
    "fold_msb_negation",
    "unfold_group_codes",
    "plane_truncation_bound",
]


def reinterpret_scale_zero(scale, zero, bits: int):
    """Eq. 2: adjust (s, z) -> (s', z') for the symmetric odd grid."""
    scale_p = scale / 2.0
    zero_p = 2.0 * zero + 1.0 - (1 << bits)
    return scale_p, zero_p


def reinterpret_codes(q, bits: int):
    """Eq. 2: unsigned codes q -> symmetric odd integers q' = 2q - (2^B - 1)."""
    q = jnp.asarray(q)
    return 2 * q.astype(jnp.int32) - ((1 << bits) - 1)


def plane_scales_for(bits: int, ternary: bool = False) -> np.ndarray:
    """Per-plane scales: [1,2,4,...] for the odd grid, [1,1] for ternary."""
    if ternary:
        return np.array([1.0, 1.0], dtype=np.float32)
    return (2.0 ** np.arange(bits)).astype(np.float32)


def codes_to_sign_planes(q, bits: int):
    """Unsigned codes [.., K] -> sign planes σ_b ∈ {0,1} of shape [.., K, B].

    Bit b of the code is plane b; plane value 1 means σ=+1, 0 means σ=-1.
    Exactness: sum_b 2^b (2*plane_b - 1) == 2q - (2^B - 1) == q'.
    """
    q = jnp.asarray(q).astype(jnp.uint8)
    shifts = jnp.arange(bits, dtype=jnp.uint8)
    return ((q[..., None] >> shifts) & 1).astype(jnp.uint8)


def ternary_to_sign_planes(t):
    """Ternary codes {-1,0,1} [.., K] -> two {0,1} sign planes [.., K, 2].

    plane_a = 1 iff t >= 0 ; plane_b = 1 iff t > 0 ;  (σ_a + σ_b)/2 == t.
    """
    t = jnp.asarray(t).astype(jnp.int32)
    pa = (t >= 0).astype(jnp.uint8)
    pb = (t > 0).astype(jnp.uint8)
    return jnp.stack([pa, pb], axis=-1)


def plane_truncation_bound(plane_scales, keep: int) -> float:
    """Worst-case |q'_full - q'_view| when keeping only the top ``keep`` planes.

    Consequence 1 above makes a plane-sliced view of the packed buffer a
    *free* coarser model (the self-speculation draft): because every σ_b is
    exactly ±1 — never 0 — dropping plane b perturbs q' by exactly ±ps_b,
    so the truncation error is bounded by the dropped plane-scale sum
    (e.g. keeping the top 2 of 4 odd-grid planes: |Δq'| ≤ 1 + 2 = 3, i.e.
    3·s' in real units).  The bound is tight and mean-zero over random
    low-plane bits, which is why the draft's argmax tracks the target's.
    """
    dropped = tuple(plane_scales)[: len(tuple(plane_scales)) - keep]
    return float(sum(dropped))


def fold_msb_negation(planes, k_group: int):
    """Eq. 6: offline fold of the MSB-conditional bit negation.

    Args:
      planes: {0,1} sign planes, shape [N, K, B]  (K divisible by k_group).
      k_group: table group length K (paper uses 4; TPU DSE favours 2).

    Returns:
      sign: uint8 [N, G, B]   — 1 where the group's MSB plane-bit is 1
                                (result must be negated at accumulate time),
      idx:  uint8 [N, G, B]   — (k_group-1)-bit table index with the
                                conditional bit-flip already applied.

    Lookup semantics (ref oracle): for a group with raw pattern bits
    ``w_0..w_{K-1}`` (σ_i = 2 w_i - 1) and half-table
    ``T[e] = Σ_i a_i σ_i(e)`` built with σ_{K-1} = -1::

        dot(a, σ) == (1 - 2*sign) * T[idx]
    """
    n, k, b = planes.shape
    if k % k_group:
        raise ValueError(f"K={k} not divisible by k_group={k_group}")
    g = k // k_group
    grp = planes.reshape(n, g, k_group, b)
    msb = grp[:, :, k_group - 1, :]  # [N, G, B]
    mask = (1 << (k_group - 1)) - 1
    if k_group == 1:
        idx = jnp.zeros((n, g, b), dtype=jnp.uint8)
        return msb.astype(jnp.uint8), idx
    weights = (1 << jnp.arange(k_group - 1, dtype=jnp.uint32)).astype(jnp.uint32)
    # Reduce the (k_group-1) low bit positions (axis 2) into an integer index.
    low = jnp.tensordot(
        grp[:, :, : k_group - 1, :].astype(jnp.uint32), weights, axes=[[2], [0]]
    ).astype(jnp.uint32)  # [N, G, B]
    flipped = (~low) & mask
    idx = jnp.where(msb.astype(bool), flipped, low).astype(jnp.uint8)
    return msb.astype(jnp.uint8), idx


def unfold_group_codes(sign, idx, k_group: int):
    """Inverse of :func:`fold_msb_negation` — recover raw {0,1} plane bits.

    Returns planes of shape [N, K, B].
    """
    n, g, b = idx.shape
    mask = (1 << (k_group - 1)) - 1
    low = jnp.where(sign.astype(bool), (~idx.astype(jnp.int32)) & mask, idx.astype(jnp.int32))
    bits = []
    for i in range(k_group - 1):
        bits.append(((low >> i) & 1).astype(jnp.uint8))
    bits.append(sign.astype(jnp.uint8))
    grp = jnp.stack(bits, axis=2)  # [N, G, k_group, B]
    return grp.reshape(n, g * k_group, b)
