"""Measured-time kernel autotuner with a persistent on-disk tuning cache.

The paper's compilation story (§3.2.2, Fig 11/14) searches tile shapes with
*measured* feedback instead of trusting an analytical model. This module is
that search for the Pallas mpGEMM stack:

  * ``candidate_configs`` enumerates (fusion, bm, bn, bg) candidates for one
    mpGEMM shape, using the LMMA memory-size scheduler and the DSE traffic
    model (``core.lmma._score`` / ``core.dse.tile_traffic``) as the *prior*
    — the analytical score orders the space, wall-clock decides.
  * ``tune_mpgemm`` times each candidate on the real kernels (one jit per
    candidate), recording **compile time and steady-state time separately**
    — the two failure modes of a bad dispatch (compile-shape churn vs a
    genuinely bad tile) look identical in end-to-end latency and are only
    distinguishable with both numbers.
  * ``TuningCache`` persists winners to a JSON file keyed by
    (M, N, G, k_group, weight_bits, dtype, table_quant), with the backend
    and jax version recorded at file level. Loads are tolerant: a corrupt /
    truncated / format-version-mismatched file degrades to an empty cache
    with a warning (dispatch falls back to heuristics); a cache written on
    a *different backend* is kept but every entry is re-validated and
    re-clamped at lookup so it can never crash dispatch. Saves are atomic
    (write-to-temp + ``os.replace``) so concurrent writers can interleave
    without ever leaving a torn file.

Dispatch integration: ``fusion="tuned"`` (kernels/ops.py) consults the
module-level *active* cache at trace time — a dict lookup, microseconds —
and falls back to the ``"auto"`` heuristic on a miss. Measurement never
happens inside a trace; populate the cache offline via ``tune_mpgemm`` /
``pretune_params`` (the serving engine and ``benchmarks/bench_autotune.py``
both drive it).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import lmma
from repro.core.lmma import (LMMADescriptor, TileSchedule, fused_tile_bytes,
                             select_fusion)

__all__ = ["TunedConfig", "TuningCache", "shape_key", "candidate_configs",
           "tune_mpgemm", "pretune_params", "configure", "deactivate",
           "get_active", "lookup_tuned", "lookup_fusion_any"]

CACHE_FORMAT_VERSION = 1

# block-shape candidate axes (the scheduler's own lattice)
_BM_CANDS = (8, 16, 32, 64, 128, 256)
_BN_CANDS = (128, 256, 512, 1024, 2048)
_BG_CANDS = (8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One dispatch decision for one mpGEMM shape, plus its measurements."""

    fusion: str                 # "fused" | "staged"
    block_m: int
    block_n: int
    block_g: int
    steady_ms: float = 0.0      # median post-compile wall-clock
    compile_ms: float = 0.0     # first-call (trace + compile) wall-clock
    heuristic_ms: float = 0.0   # same-pass steady time of the "auto" pick
    source: str = "heuristic"   # "heuristic" | "measured"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def blocks(self) -> Tuple[int, int, int]:
        return (self.block_m, self.block_n, self.block_g)


def shape_key(m: int, n: int, g: int, k_group: int, w_bits: int, *,
              dtype: str = "f32",
              table_quant: Optional[str] = "per_row") -> str:
    """Cache key for one mpGEMM problem: shape + dtype + quant layout."""
    return (f"m{m}.n{n}.g{g}.kg{k_group}.w{w_bits}."
            f"{dtype}.tq{table_quant or 'none'}")


def _realign_bg(bg: int, planes: int, k_group: int) -> int:
    """Packed-stream byte alignment (same rule as ops._clamp_blocks)."""
    bg = max(1, int(bg))
    while (bg * planes * k_group) % 8:
        bg *= 2
    return bg


def sanitize_config(cfg: TunedConfig, m: int, n: int, g: int, k_group: int,
                    planes: int,
                    vmem_budget: int = lmma.VMEM_BYTES) -> Optional[TunedConfig]:
    """Force a (possibly foreign) cache entry into a valid dispatch decision.

    Returns None when the entry is unusable (bad types / non-positive
    blocks / unknown fusion); otherwise clamps blocks to the problem,
    re-applies the packed-stream byte alignment, and demotes ``fused`` to
    ``staged`` when the fused working set cannot fit VMEM — the exact
    constraints ops._clamp_blocks / select_fusion enforce, so a sanitized
    config can never crash the wrappers.
    """
    try:
        bm, bn, bg = int(cfg.block_m), int(cfg.block_n), int(cfg.block_g)
        fusion = str(cfg.fusion)
    except (TypeError, ValueError):
        return None
    if fusion not in ("fused", "staged") or bm <= 0 or bn <= 0 or bg <= 0:
        return None
    bm = min(bm, max(8, m))
    bn = min(bn, max(1, n))
    bg = _realign_bg(min(bg, max(1, g)), planes, k_group)
    desc = LMMADescriptor(m=m, n=n, k=g * k_group, w_bits=planes,
                          k_group=k_group)
    if fusion == "fused" and fused_tile_bytes(bm, bn, bg, desc) > vmem_budget:
        fusion = "staged"
    return dataclasses.replace(cfg, fusion=fusion, block_m=bm, block_n=bn,
                               block_g=bg)


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------

class TuningCache:
    """JSON-backed {shape_key -> TunedConfig} map with durable load/save."""

    def __init__(self, path: Optional[str] = None, *,
                 backend: Optional[str] = None):
        if backend is None:
            import jax
            backend = jax.default_backend()
        import jax
        self.path = path
        self.backend = backend
        self.jax_version = jax.__version__
        self.entries: Dict[str, TunedConfig] = {}
        self.foreign = False      # loaded from a different backend/jax
        self.hits = 0
        self.misses = 0
        self.sanitized = 0        # lookups whose entry needed repair/drop
        if path is not None and os.path.exists(path):
            self._load(path)

    # -- durability -------------------------------------------------------
    def _load(self, path: str):
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            warnings.warn(f"tuning cache {path!r} unreadable ({e}); "
                          "falling back to heuristic dispatch")
            return
        if not isinstance(raw, dict) \
                or raw.get("version") != CACHE_FORMAT_VERSION \
                or not isinstance(raw.get("entries"), dict):
            warnings.warn(
                f"tuning cache {path!r} has unknown format "
                f"(version={raw.get('version') if isinstance(raw, dict) else '?'}, "
                f"want {CACHE_FORMAT_VERSION}); ignoring it")
            return
        if raw.get("backend") != self.backend \
                or raw.get("jax_version") != self.jax_version:
            self.foreign = True
            warnings.warn(
                f"tuning cache {path!r} was tuned on "
                f"backend={raw.get('backend')!r}/jax={raw.get('jax_version')!r} "
                f"(running {self.backend!r}/{self.jax_version}); entries will "
                "be re-validated at lookup")
        fields = {f.name for f in dataclasses.fields(TunedConfig)}
        for key, ent in raw["entries"].items():
            if not isinstance(ent, dict):
                continue
            try:
                cfg = TunedConfig(**{k: v for k, v in ent.items()
                                     if k in fields})
                int(cfg.block_m), int(cfg.block_n), int(cfg.block_g)
            except (TypeError, ValueError):
                continue  # skip malformed entries, keep the rest
            self.entries[key] = cfg

    def save(self, path: Optional[str] = None):
        """Atomic save: temp file in the target dir + os.replace."""
        path = path or self.path
        if path is None:
            raise ValueError("TuningCache has no path to save to")
        payload = {
            "version": CACHE_FORMAT_VERSION,
            "backend": self.backend,
            "jax_version": self.jax_version,
            "entries": {k: v.as_dict() for k, v in sorted(self.entries.items())},
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tuning_cache.",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, path)  # atomic on POSIX: readers never see a torn file
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.path = path

    # -- access -----------------------------------------------------------
    def put(self, key: str, cfg: TunedConfig):
        self.entries[key] = cfg

    def lookup(self, key: str) -> Optional[TunedConfig]:
        cfg = self.entries.get(key)
        if cfg is None:
            self.misses += 1
        else:
            self.hits += 1
        return cfg

    def counters(self) -> dict:
        """Observability snapshot: lookup traffic + durability state.

        ``sanitized`` counts lookups whose entry had to be repaired (blocks
        re-clamped, fused demoted to staged) or dropped entirely — nonzero
        on a healthy same-backend cache means the cache file is stale or
        foreign. Exposed via ``engine.stats()['tuning_cache']`` and
        ``bench_autotune.py``."""
        return {
            "entries": len(self.entries),
            "hits": self.hits,
            "misses": self.misses,
            "sanitized": self.sanitized,
            "foreign": self.foreign,
            "backend": self.backend,
            "path": self.path,
        }

    def __len__(self):
        return len(self.entries)


# ---------------------------------------------------------------------------
# module-level active cache (what fusion="tuned" consults at trace time)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[TuningCache] = None


def configure(path: Optional[str], **kw) -> TuningCache:
    """Load (or create) the active tuning cache used by ``fusion="tuned"``."""
    global _ACTIVE
    _ACTIVE = TuningCache(path, **kw)
    return _ACTIVE


def deactivate():
    global _ACTIVE
    _ACTIVE = None


def get_active() -> Optional[TuningCache]:
    return _ACTIVE


def lookup_tuned(m: int, n: int, g: int, k_group: int, planes: int, *,
                 w_bits: Optional[int] = None, dtype: str = "f32",
                 table_quant: Optional[str] = "per_row"
                 ) -> Optional[TunedConfig]:
    """Trace-time lookup for dispatch: sanitized entry or None (miss)."""
    if _ACTIVE is None:
        return None
    key = shape_key(m, n, g, k_group,
                    planes if w_bits is None else w_bits,
                    dtype=dtype, table_quant=table_quant)
    cfg = _ACTIVE.lookup(key)
    if cfg is None:
        return None
    out = sanitize_config(cfg, m, n, g, k_group, planes)
    if out != cfg:  # repaired (clamped/demoted) or dropped (None)
        _ACTIVE.sanitized += 1
    return out


def lookup_fusion_any(m: int, g: int, k_group: int, w_bits: int) -> Optional[str]:
    """Best-effort fusion vote for table-sharing decisions (layers.make_table
    doesn't know N). Returns the fusion of the largest-N tuned entry whose
    (M, G, k_group, bits) match, or None when nothing matches."""
    if _ACTIVE is None:
        return None
    prefix = f"m{m}."
    want = f".g{g}.kg{k_group}.w{w_bits}."
    best_n, best = -1, None
    for key, cfg in _ACTIVE.entries.items():
        if not key.startswith(prefix) or want not in key:
            continue
        try:
            n = int(key.split(".n")[1].split(".")[0])
        except (IndexError, ValueError):
            continue
        if n > best_n and cfg.fusion in ("fused", "staged"):
            best_n, best = n, cfg.fusion
    return best


# ---------------------------------------------------------------------------
# candidate generation: DSE prior over the scheduler's lattice
# ---------------------------------------------------------------------------

def candidate_configs(m: int, n: int, g: int, k_group: int, planes: int, *,
                      vmem_budget: int = lmma.VMEM_BYTES,
                      max_candidates: int = 6) -> List[TunedConfig]:
    """Analytically-ranked search space for one mpGEMM shape.

    The heuristic pick (ops.pick_blocks + select_fusion — what ``"auto"``
    would do) is always candidate 0, so measured tuning can never select a
    config worse than the heuristic *as measured in the same pass*. The rest
    are the top-scoring tiles under the LMMA MACs-per-byte prior, each in
    its VMEM-feasible fusion mode (plus the opposite mode for the best tile,
    so measurement — not the model — settles fused-vs-staged).
    """
    from repro.kernels.ops import pick_blocks  # lazy: ops imports autotune

    desc = LMMADescriptor(m=m, n=n, k=g * k_group, w_bits=planes,
                          k_group=k_group)
    scored = []
    seen = set()
    for bm in (c for c in _BM_CANDS if c <= max(m, 8)):
        for bn in (c for c in _BN_CANDS if c <= max(n, _BN_CANDS[0])):
            for bg in (c for c in _BG_CANDS if c <= max(g, _BG_CANDS[0])):
                bg = _realign_bg(min(bg, max(1, g)), planes, k_group)
                bmc = min(bm, max(8, m))
                bnc = min(bn, max(1, n))
                if (bmc, bnc, bg) in seen:
                    continue
                seen.add((bmc, bnc, bg))
                t, w, a = lmma._tile_bytes(bmc, bnc, bg, desc)
                tot = 2 * (t + w) + a
                if tot > vmem_budget:
                    continue
                ts = TileSchedule(bmc, bnc, bg, t, w, a, tot)
                scored.append((lmma._score(ts, desc, True), ts))
    scored.sort(key=lambda s: -s[0])

    hm, hn, hg = pick_blocks(m, n, g, k_group, planes)
    hm, hn, hg = (min(hm, max(8, m)), min(hn, max(1, n)),
                  _realign_bg(min(hg, max(1, g)), planes, k_group))
    hfusion = select_fusion(desc, TileSchedule(hm, hn, hg, 0, 0, 0, 0),
                            vmem_budget=vmem_budget)
    out = [TunedConfig(hfusion, hm, hn, hg, source="heuristic")]
    emitted = {(hfusion, hm, hn, hg)}
    for _, ts in scored:
        if len(out) >= max_candidates:
            break
        fusion = ("fused"
                  if fused_tile_bytes(ts.bm, ts.bn, ts.bg, desc) <= vmem_budget
                  else "staged")
        cand = (fusion, ts.bm, ts.bn, ts.bg)
        if cand in emitted:
            continue
        emitted.add(cand)
        out.append(TunedConfig(*cand, source="measured"))
    # let measurement arbitrate fused-vs-staged on the best tile
    if out and len(out) < max_candidates + 1:
        top = out[1] if len(out) > 1 else out[0]
        alt = "staged" if top.fusion == "fused" else "fused"
        if alt == "staged" or fused_tile_bytes(
                top.block_m, top.block_n, top.block_g, desc) <= vmem_budget:
            cand = (alt, top.block_m, top.block_n, top.block_g)
            if cand not in emitted:
                out.append(TunedConfig(*cand, source="measured"))
    return out


# ---------------------------------------------------------------------------
# measured tuning
# ---------------------------------------------------------------------------

def _measure(fn, args, repeats: int) -> Tuple[float, float]:
    """(compile_ms, steady_ms): first call vs median of post-compile calls."""
    import jax

    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_ms = (time.perf_counter() - t0) * 1e3
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    times.sort()
    return compile_ms, times[len(times) // 2]


def tune_mpgemm(m: int, qw, *, table_quant: Optional[str] = "per_row",
                cache: Optional[TuningCache] = None, repeats: int = 3,
                max_candidates: int = 6, interpret: Optional[bool] = None,
                seed: int = 0, verbose: bool = False
                ) -> Tuple[TunedConfig, List[TunedConfig]]:
    """Measure candidates for one (M × qw) mpGEMM and record the winner.

    Returns (best, all_measured). Each measured config carries compile_ms
    and steady_ms — together they distinguish compile-shape churn (high
    compile, fine steady) from a genuinely bad tile (fine compile, slow
    steady). Winner selection uses steady_ms only; compile cost is paid
    once per shape and must not bias the steady-state choice.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops  # lazy: ops imports autotune

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    g, planes = qw.g, qw.num_planes
    x = jax.random.normal(jax.random.key(seed), (m, qw.k_total), jnp.float32)
    measured: List[TunedConfig] = []
    for cand in candidate_configs(m, qw.n, g, qw.k_group, planes,
                                  max_candidates=max_candidates):
        fn = jax.jit(functools.partial(
            ops.lut_mpgemm, table_quant=table_quant, fusion=cand.fusion,
            block_m=cand.block_m, block_n=cand.block_n,
            block_g=cand.block_g, interpret=interpret))
        try:
            compile_ms, steady_ms = _measure(fn, (x, qw), repeats)
        except Exception as e:  # candidate invalid on this backend: skip
            warnings.warn(f"autotune candidate {cand.blocks} "
                          f"({cand.fusion}) failed: {e}")
            continue
        measured.append(dataclasses.replace(
            cand, compile_ms=compile_ms, steady_ms=steady_ms))
        if verbose:
            print(f"  cand {cand.fusion:6s} bm={cand.block_m:<4d}"
                  f"bn={cand.block_n:<5d}bg={cand.block_g:<4d}"
                  f"compile {compile_ms:8.1f} ms  steady {steady_ms:8.2f} ms"
                  f"  [{cand.source}]")
    if not measured:
        raise RuntimeError(f"no viable autotune candidate for m={m}, {qw}")
    best = min(measured, key=lambda c: c.steady_ms)
    heur = next((c for c in measured if c.source == "heuristic"), best)
    best = dataclasses.replace(best, source="measured",
                               heuristic_ms=heur.steady_ms)
    if cache is not None:
        cache.put(shape_key(m, qw.n, g, qw.k_group, planes,
                            table_quant=table_quant), best)
    return best, measured


def collect_qw_shapes(params) -> List:
    """Unique QuantizedWeight leaves in a param tree (by shape signature).

    Batched QuantizedWeights (vmapped MoE experts: packed [E, N, bytes])
    are represented by their first slice — every expert shares the shape,
    so one tuned entry covers the whole batched einsum dispatch.
    """
    from repro.core.quantize import QuantizedWeight

    found, seen = [], set()

    def walk(node):
        if isinstance(node, QuantizedWeight):
            if node.packed is None:
                return  # offline-CW store: no packed planes to tile-tune
            if node.packed.ndim > 2:
                # vmap-batched (stacked layers / experts, possibly nested):
                # every slice shares the shape, so tune on the first one
                ix = (0,) * (node.packed.ndim - 2)
                node = QuantizedWeight(
                    node.packed[ix], node.scale[ix],
                    None if node.zero_prime is None else node.zero_prime[ix],
                    node.plane_scales, bits=node.bits, k_group=node.k_group,
                    k_total=node.k_total, n=node.n)
            sig = (node.n, node.k_total, node.k_group, node.num_planes)
            if sig not in seen:
                seen.add(sig)
                found.append(node)
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return found


def _local_slice(qw, mp: int):
    """The [n/mp, bytes] shard of a packed weight one model-parallel device
    holds — what its mpGEMM actually runs, hence what must be measured."""
    from repro.core.quantize import QuantizedWeight
    if mp <= 1 or qw.n % mp:
        return qw
    nl = qw.n // mp
    return QuantizedWeight(
        qw.packed[:nl], qw.scale[:nl],
        None if qw.zero_prime is None else qw.zero_prime[:nl],
        qw.plane_scales, bits=qw.bits, k_group=qw.k_group,
        k_total=qw.k_total, n=nl)


def pretune_params(params, ms: Sequence[int], *,
                   cache: Optional[TuningCache] = None,
                   table_quant: Optional[str] = "per_row",
                   plan=None, repeats: int = 2, max_candidates: int = 4,
                   skip_cached: bool = True, verbose: bool = False) -> int:
    """Tune every (M, projection-shape) pair a serving config will dispatch.

    ``ms`` is the list of M values the engine emits (decode: max_batch;
    prefill: prefill_chunk). Under an AxisPlan the tuned unit is the
    PER-SHARD tile: each qw is sliced to the [n/mp] rows one model-parallel
    device holds and M is divided over the batch axis, producing cache
    entries keyed by the local shapes ``kernels.ops.resolve_dispatch``
    looks up at trace time inside a ``plan_scope``. Returns the number of
    shapes tuned; entries already in the cache are skipped unless
    ``skip_cached=False``. Call ``cache.save()`` afterwards to persist.
    """
    cache = cache if cache is not None else get_active()
    mp = dp = 1
    if plan is not None:
        mp, dp = plan.axis_size("model"), plan.axis_size("batch")
    tuned = 0
    for qw in collect_qw_shapes(params):
        qw = _local_slice(qw, mp)
        for m in ms:
            if dp > 1 and m % dp == 0:
                m //= dp
            key = shape_key(m, qw.n, qw.g, qw.k_group, qw.num_planes,
                            table_quant=table_quant)
            if skip_cached and cache is not None and key in cache.entries:
                continue
            if verbose:
                print(f"tuning {key} ...")
            tune_mpgemm(m, qw, table_quant=table_quant, cache=cache,
                        repeats=repeats, max_candidates=max_candidates,
                        verbose=verbose)
            tuned += 1
    return tuned
