"""Public mpGEMM API — the paper's contribution as a composable JAX op.

``mpgemm(x, qw, mode=...)`` multiplies high-precision activations with packed
low-bit weights.  Modes:

  * ``"dequant"``     — unpack→upcast→GEMM (paper Fig. 2b baseline; what a
                        stock accelerator must do).
  * ``"lut_xla"``     — LUT-based: DFG-split table precompute + single
                        ``T @ CW`` GEMM (TPU-native lookup, DESIGN.md §2);
                        with ``table_quant='per_row'`` the GEMM runs int8.
  * ``"lut_pallas"``  — the Pallas LUT Tensor Core kernel (kernels/); the
                        ``fusion`` knob picks the fused single-kernel
                        precompute→lookup pipeline (table stays in VMEM,
                        §3.1.1) vs the staged two-kernel one.
  * ``"fp16"``        — dense float GEMM on dequantized weights cached as a
                        regular array; reference/upper-precision path.

The DFG transformation (§3.1.1) is first-class: ``precompute_tables`` is an
independent operator whose result can be passed back via ``table=`` so the
framework (or XLA fusion) amortizes it across every consumer — e.g. Q/K/V
projections share one table of their common input.

``mpgemm`` handles arbitrary leading batch dims; the contraction is always
the last axis of ``x`` against ``qw.k_total``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .quantize import QuantizedWeight, dequantize
from .table import Table, precompute_table

__all__ = ["mpgemm", "precompute_tables", "resolve_table_quant",
           "MPGEMM_MODES", "FUSION_MODES"]

MPGEMM_MODES = ("fp16", "dequant", "lut_xla", "lut_pallas")
# lut_pallas precompute placement (owned here, next to the mode it modifies,
# so config/model validation never has to import the kernel stack):
# "auto" = LMMA VMEM heuristic, "tuned" = measured-time autotune cache
# (core.autotune; falls back to "auto" on a cache miss)
FUSION_MODES = ("auto", "fused", "staged", "tuned")


def resolve_table_quant(table_quant: Optional[str]) -> Optional[str]:
    """Map the ``"auto"`` table-precision knob to a concrete mode.

    Per-row INT8 tables are the paper's format — they feed an int8 MXU (or
    the LUT unit's int8 datapath) and halve table bytes. On backends
    without an int8 GEMM fast path (CPU emulation), quantizing the table
    costs extra ops AND accuracy, so ``"auto"`` resolves to float tables
    there. Explicit ``"per_row"``/``"per_group"``/``None`` pass through.
    """
    if table_quant == "auto":
        return "per_row" if jax.default_backend() == "tpu" else None
    return table_quant


def precompute_tables(x, k_group: int = 4, table_quant: Optional[str] = "per_row") -> Table:
    """Independent table-precompute operator (fuse me with your previous op)."""
    table_quant = resolve_table_quant(table_quant)
    lead = x.shape[:-1]
    t = precompute_table(x.reshape(-1, x.shape[-1]), k_group, table_quant)
    del lead  # table stays flat [M, G, E]; mpgemm reshapes the output
    return t


def _lut_xla(x2d, qw: QuantizedWeight, table_quant, table: Optional[Table]):
    from repro.kernels import ref  # local import to avoid cycles

    return ref.ref_lut_mpgemm_matmul(x2d, qw, table_quant=table_quant, table=table)


def _lut_pallas(x2d, qw: QuantizedWeight, table_quant, table: Optional[Table],
                fusion, interpret):
    from repro.kernels import ops

    return ops.lut_mpgemm(x2d, qw, table_quant=table_quant, table=table,
                          fusion=fusion, interpret=interpret)


def mpgemm(
    x: jax.Array,
    qw: QuantizedWeight,
    *,
    mode: str = "lut_xla",
    table_quant: Optional[str] = "per_row",
    table: Optional[Table] = None,
    fusion: str = "auto",
    interpret: Optional[bool] = None,
    out_dtype=None,
) -> jax.Array:
    """y[..., n] = Σ_k x[..., k] · W[n, k] with W stored low-bit packed.

    ``fusion`` (lut_pallas only) picks the precompute placement: "fused"
    computes the table in-VMEM inside the mpGEMM kernel (never hits HBM),
    "staged" materializes it between two kernels, "auto" lets the LMMA tile
    scheduler decide from the VMEM budget, "tuned" uses the persistent
    measured-time autotune cache (auto on a miss). Ignored when ``table=``
    is supplied — a shared table is by definition staged.
    """
    if mode not in MPGEMM_MODES:
        raise ValueError(f"mode {mode!r} not in {MPGEMM_MODES}")
    table_quant = resolve_table_quant(table_quant)
    if x.shape[-1] != qw.k_total:
        raise ValueError(f"contract dim {x.shape[-1]} != k_total {qw.k_total}")
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    x2d = x.reshape(-1, qw.k_total)

    if mode == "fp16":
        w = dequantize(qw).astype(x.dtype)
        out = jnp.dot(x2d, w.T, preferred_element_type=jnp.float32)
    elif mode == "dequant":
        # Unpack + upcast happen *inside* the jitted graph: HLO parameter
        # bytes stay truly low-bit; the upcast is the baseline's cost.
        w = dequantize(qw).astype(jnp.bfloat16)
        out = jnp.dot(x2d.astype(jnp.bfloat16), w.T,
                      preferred_element_type=jnp.float32)
    elif mode == "lut_xla":
        out = _lut_xla(x2d, qw, table_quant, table)
    else:  # lut_pallas
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = _lut_pallas(x2d, qw, table_quant, table, fusion, interpret)
    return out.reshape(*lead, qw.n).astype(out_dtype)
