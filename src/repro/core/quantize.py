"""Weight quantizers producing the framework's low-bit weight format.

``QuantizedWeight`` is the single weight container consumed by every mpGEMM
mode (dequant / lut_xla / lut_pallas) and by the serving stack:

  * ``packed``       uint8 [N, ceil(K*B/8)] — folded group codes (Eq. 6
                     applied offline), the true B-bit HBM format,
  * ``scale``        float32 [N]            — s' = s/2 (reinterpreted),
  * ``zero_prime``   float32 [N] or None    — z' (None ⇒ symmetric, z'=0),
  * ``plane_scales`` float32 [B]            — [1,2,4..] or [1,1] (ternary),
  * ``bits, k_group, k_total, n``           — static metadata.

Quantizers:
  * ``quantize_symmetric``  — absmax onto the odd grid (z'=0). This is the
    reinterpreted form of the paper's Eq. 1-2 with z = (2^B-1)/2.
  * ``quantize_asymmetric`` — min/max affine, reinterpreted via Eq. 2
    (exercises the zero-point correction path).
  * ``quantize_ternary``    — BitNet b1.58 absmean ternary, two ±1 planes.
  * ``fake_quant``          — straight-through-estimator QAT fake-quant for
    the training forward pass (paper §5: applying mpGEMM to training fwd).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import packing, reinterpret

__all__ = [
    "QuantizedWeight",
    "quantize_symmetric",
    "quantize_asymmetric",
    "quantize_ternary",
    "quantize",
    "dequantize",
    "fake_quant",
]


@jax.tree_util.register_pytree_with_keys_class
class QuantizedWeight:
    """Pytree container for packed low-bit weights (see module docstring)."""

    def __init__(self, packed, scale, zero_prime, plane_scales, *, bits, k_group, k_total, n, cw=None,
                 plane_start=0, stored_planes=None):
        self.packed = packed
        self.scale = scale
        self.zero_prime = zero_prime
        # optional offline-expanded combined-lookup matrix CW [G*E, N] int8
        # (the serving format for memory-bound decode: no per-step CW build)
        self.cw = cw
        # plane scales are STATIC metadata (kernels unroll the bit-serial
        # loop over them), never traced arrays.
        self.plane_scales = tuple(float(s) for s in plane_scales)
        self.bits = int(bits)
        self.k_group = int(k_group)
        self.k_total = int(k_total)
        self.n = int(n)
        # plane-sliced execution view (paper §3.1.2: the packed tensor IS a
        # sum of ±1 planes, so a contiguous plane subrange of the SAME
        # buffer is a coarser-precision model for free). ``stored_planes``
        # is the plane count of the underlying packed layout (governs the
        # byte math); ``plane_start`` is where this view's planes begin.
        # A full-precision weight has plane_start == 0 and
        # stored_planes == len(plane_scales).
        self.plane_start = int(plane_start)
        self.stored_planes = (len(self.plane_scales) if stored_planes is None
                              else int(stored_planes))

    # -- pytree protocol ----------------------------------------------------
    # Keyed flattening so tree_flatten_with_path yields NAMED child paths
    # (".../qw/packed", ".../qw/scale", ...) — the sharding-rule regexes in
    # distributed/sharding.py match on these names; with anonymous
    # flattening the paths were numeric indices and no packed-weight rule
    # could ever fire.
    def tree_flatten_with_keys(self):
        children = ((jax.tree_util.GetAttrKey("packed"), self.packed),
                    (jax.tree_util.GetAttrKey("scale"), self.scale),
                    (jax.tree_util.GetAttrKey("zero_prime"), self.zero_prime),
                    (jax.tree_util.GetAttrKey("cw"), self.cw))
        aux = (self.plane_scales, self.bits, self.k_group, self.k_total,
               self.n, self.plane_start, self.stored_planes)
        return children, aux

    def tree_flatten(self):
        children = (self.packed, self.scale, self.zero_prime, self.cw)
        aux = (self.plane_scales, self.bits, self.k_group, self.k_total,
               self.n, self.plane_start, self.stored_planes)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale, zero_prime, cw = children
        plane_scales, bits, k_group, k_total, n, plane_start, stored = aux
        return cls(packed, scale, zero_prime, plane_scales,
                   bits=bits, k_group=k_group, k_total=k_total, n=n, cw=cw,
                   plane_start=plane_start, stored_planes=stored)

    # -- helpers -------------------------------------------------------------
    @property
    def num_planes(self) -> int:
        return len(self.plane_scales)

    @property
    def g(self) -> int:
        return self.k_total // self.k_group

    @property
    def is_plane_sliced(self) -> bool:
        return (self.plane_start != 0
                or self.stored_planes != self.num_planes)

    def sign_idx(self):
        """Unpack to (sign, idx) uint8 [N, G, B].

        The packed byte stream is group-major ((g, b) at field g*B + b), so
        a plane-sliced view CANNOT truncate bytes: unpack at the stored
        plane count, then slice this view's plane range.
        """
        sign, idx = packing.unpack_group_codes(
            self.packed, self.k_group, self.g, self.stored_planes)
        if self.is_plane_sliced:
            sl = slice(self.plane_start, self.plane_start + self.num_planes)
            sign, idx = sign[..., sl], idx[..., sl]
        return sign, idx

    def plane_slice(self, keep: int) -> "QuantizedWeight":
        """Top-``keep``-planes draft view of the SAME packed buffer.

        Zero-copy: the returned weight shares ``packed``/``scale``/
        ``zero_prime`` with ``self`` (no extra weight HBM).  Dropping the
        ``B - keep`` low-order planes perturbs each weight by at most
        ``s'·(2^(B-keep) - 1)`` — the sign planes are ±1, never 0, so the
        dropped contribution is mean-zero noise and ``z'`` stays unbiased.
        CW-store weights cannot be sliced (CW bakes all planes in).
        """
        if keep >= self.num_planes:
            return self
        if keep < 1:
            raise ValueError(f"plane_slice(keep={keep}): need >= 1 plane")
        if self.packed is None:
            raise ValueError(
                "plane_slice needs the packed store: the offline CW matrix "
                "bakes every plane into its entries and is not re-sliceable "
                "(pin quant['store']='packed' before converting)")
        start = self.plane_start + (self.num_planes - keep)
        return QuantizedWeight(
            self.packed, self.scale, self.zero_prime,
            self.plane_scales[self.num_planes - keep:],
            bits=self.bits, k_group=self.k_group, k_total=self.k_total,
            n=self.n, cw=None, plane_start=start,
            stored_planes=self.stored_planes)

    def storage_bits_per_weight(self) -> float:
        return self.packed.shape[1] * 8 / self.k_total

    def __repr__(self):
        sl = (f", view=[{self.plane_start}:"
              f"{self.plane_start + self.num_planes}]/{self.stored_planes}"
              if self.is_plane_sliced else "")
        return (f"QuantizedWeight(n={self.n}, k={self.k_total}, bits={self.bits}, "
                f"k_group={self.k_group}, planes={self.num_planes}{sl})")


def _pack_planes(planes, k_group):
    sign, idx = reinterpret.fold_msb_negation(planes, k_group)
    return packing.pack_group_codes(sign, idx, k_group)


def quantize_symmetric(w: jax.Array, bits: int, k_group: int = 4) -> QuantizedWeight:
    """MSE-optimal symmetric quantization onto the odd grid {±1, ±3, ...}·s'.

    w: float [N, K] (output-major). z' = 0 by construction. The per-row
    scale is not plain absmax: a per-row grid search over clip ratios
    r·absmax/qmax (r ∈ [0.6, 1.0], the AWQ/TensorRT-LLM recipe) picks the
    scale minimizing squared reconstruction error — clipping a heavy-tailed
    row's outliers buys a finer grid for the bulk of its mass. Every scale
    on the grid keeps the odd-grid invariant (dequant/scale ratios are odd
    integers ≤ 2^bits − 1), so kernels and tests are agnostic to the
    choice; end-to-end it is what keeps deep stacks with shared quantized
    blocks (zamba2-style) faithful at W4.
    """
    n, k = w.shape
    wf = w.astype(jnp.float32)
    qmax = (1 << bits) - 1
    absmax = jnp.maximum(jnp.max(jnp.abs(wf), axis=1), 1e-30)  # [N]
    ratios = jnp.linspace(0.6, 1.0, 17)

    def _recon_err(r):
        s = absmax * r / qmax
        qr = jnp.clip(jnp.round((wf / s[:, None] + qmax) / 2.0), 0, qmax)
        wr = s[:, None] * (2.0 * qr - qmax)
        return jnp.sum(jnp.square(wf - wr), axis=1)  # [N]

    errs = jax.vmap(_recon_err)(ratios)              # [R, N]
    s_prime = absmax * ratios[jnp.argmin(errs, axis=0)] / qmax
    q = jnp.clip(jnp.round((wf / s_prime[:, None] + qmax) / 2.0), 0, qmax)
    planes = reinterpret.codes_to_sign_planes(q.astype(jnp.uint8), bits)
    return QuantizedWeight(
        _pack_planes(planes, k_group), s_prime, None,
        reinterpret.plane_scales_for(bits),
        bits=bits, k_group=k_group, k_total=k, n=n)


def quantize_asymmetric(w: jax.Array, bits: int, k_group: int = 4) -> QuantizedWeight:
    """Min/max affine quantization, then reinterpretation (Eq. 2)."""
    n, k = w.shape
    wf = w.astype(jnp.float32)
    wmin = jnp.min(wf, axis=1)
    wmax = jnp.max(wf, axis=1)
    qmax = (1 << bits) - 1
    s = jnp.maximum(wmax - wmin, 1e-30) / qmax
    z = -wmin / s
    q = jnp.clip(jnp.round(wf / s[:, None] + z[:, None]), 0, qmax)
    s_prime, z_prime = reinterpret.reinterpret_scale_zero(s, z, bits)
    planes = reinterpret.codes_to_sign_planes(q.astype(jnp.uint8), bits)
    return QuantizedWeight(
        _pack_planes(planes, k_group), s_prime, z_prime,
        reinterpret.plane_scales_for(bits),
        bits=bits, k_group=k_group, k_total=k, n=n)


def quantize_ternary(w: jax.Array, k_group: int = 4) -> QuantizedWeight:
    """BitNet b1.58 absmean ternary: t = clip(round(W/mean|W|), -1, 1)."""
    n, k = w.shape
    wf = w.astype(jnp.float32)
    s = jnp.maximum(jnp.mean(jnp.abs(wf), axis=1), 1e-30)  # [N]
    t = jnp.clip(jnp.round(wf / s[:, None]), -1, 1)
    planes = reinterpret.ternary_to_sign_planes(t)
    # w ≈ s·t = (s/2)·(σ_a + σ_b): plane_scales [1,1], stored scale s/2.
    return QuantizedWeight(
        _pack_planes(planes, k_group), s / 2.0, None,
        reinterpret.plane_scales_for(2, ternary=True),
        bits=2, k_group=k_group, k_total=k, n=n)


def to_cw_format(qw: QuantizedWeight) -> QuantizedWeight:
    """Offline CW expansion (§Perf B1): store the combined-lookup matrix
    CW [G*E, N] int8 instead of packed codes. 4x larger at W2/K=2 (1 byte
    per weight vs 2 bits) but decode reads it ONCE instead of rebuilding it
    every step (packed read + one-hot intermediates + CW write+read)."""
    from repro.kernels.ref import build_cw
    import jax.numpy as _jnp
    cw = build_cw(qw, _jnp.int8)
    return QuantizedWeight(None, qw.scale, qw.zero_prime, qw.plane_scales,
                           bits=qw.bits, k_group=qw.k_group,
                           k_total=qw.k_total, n=qw.n, cw=cw)


def quantize(w, bits: int, k_group: int = 4, scheme: str = "symmetric") -> QuantizedWeight:
    if scheme == "symmetric":
        return quantize_symmetric(w, bits, k_group)
    if scheme == "asymmetric":
        return quantize_asymmetric(w, bits, k_group)
    if scheme == "ternary":
        return quantize_ternary(w, k_group)
    raise ValueError(f"unknown scheme {scheme!r}")


def dequantize(qw: QuantizedWeight) -> jax.Array:
    """Reconstruct float weights [N, K]: s'·(Σ_b ps_b·σ_b − z')."""
    sign, idx = qw.sign_idx()
    planes = reinterpret.unfold_group_codes(sign, idx, qw.k_group)  # [N,K,B] {0,1}
    sigma = 2.0 * planes.astype(jnp.float32) - 1.0
    qp = jnp.einsum("nkb,b->nk", sigma, jnp.asarray(qw.plane_scales, jnp.float32))
    if qw.zero_prime is not None:
        qp = qp - qw.zero_prime[:, None]
    return qw.scale[:, None] * qp


# ---------------------------------------------------------------------------
# QAT fake-quant (straight-through estimator)
# ---------------------------------------------------------------------------

def _fq_symmetric(w, bits):
    qmax = (1 << bits) - 1
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=-1, keepdims=True), 1e-30) / qmax
    q = jnp.clip(jnp.round((w / s + qmax) / 2.0), 0, qmax)
    return s * (2.0 * q - qmax)


def _fq_ternary(w):
    s = jnp.maximum(jnp.mean(jnp.abs(w), axis=-1, keepdims=True), 1e-30)
    return s * jnp.clip(jnp.round(w / s), -1, 1)


def fake_quant(w: jax.Array, bits: int, scheme: str = "symmetric") -> jax.Array:
    """STE fake-quant: forward uses the quantized value, gradient passes through."""
    wf = w.astype(jnp.float32)
    if scheme == "ternary":
        wq = _fq_ternary(wf)
    else:
        wq = _fq_symmetric(wf, bits)
    return (w + jax.lax.stop_gradient(wq.astype(w.dtype) - w)).astype(w.dtype)
