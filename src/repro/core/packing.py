"""Bit-level packing of folded group codes into dense uint8 streams.

Storage format ("packed group codes", PGC): for each output channel n, the
``k_group``-bit fields ``field(g, b) = sign<<(K-1) | idx`` are laid out
**group-major** — position ``g*B + b`` for group g, bit-plane b — and packed
little-endian into uint8.  This is the *HBM-resident* weight format — its
byte count is exactly ``ceil(K_total * B / 8)`` per channel, i.e. true
``B``-bit weights (the paper's storage claim), independent of k_group.

Group-major layout means a K-block of ``bg`` consecutive groups occupies the
contiguous byte range ``[g0*B*K/8, (g0+bg)*B*K/8)`` covering *all* planes,
which is exactly what a K-blocked Pallas kernel wants to stream.

k_group ∈ {1, 2, 4, 8} keeps fields byte-aligned (fields never straddle a
byte), which the kernels exploit with shift/mask unpacking.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pack_group_codes", "unpack_group_codes", "packed_bytes_per_channel"]

_SUPPORTED_K = (1, 2, 4, 8)


def packed_bytes_per_channel(k_total: int, bits: int) -> int:
    return (k_total * bits + 7) // 8


def _check(k_group: int):
    if k_group not in _SUPPORTED_K:
        raise ValueError(
            f"k_group={k_group} not byte-aligned; supported: {_SUPPORTED_K}"
        )


def pack_group_codes(sign, idx, k_group: int):
    """Pack (sign, idx) [N, G, B] into uint8 [N, ceil(G*B*k_group/8)]."""
    _check(k_group)
    n, g, b = idx.shape
    field = (sign.astype(jnp.uint32) << (k_group - 1)) | idx.astype(jnp.uint32)
    field = field.reshape(n, g * b)  # group-major: position g*B + b
    fields_per_byte = 8 // k_group
    pad = (-field.shape[1]) % fields_per_byte
    if pad:
        field = jnp.pad(field, ((0, 0), (0, pad)))
    field = field.reshape(n, -1, fields_per_byte)
    shifts = (k_group * jnp.arange(fields_per_byte, dtype=jnp.uint32))
    packed = jnp.sum(field << shifts, axis=-1).astype(jnp.uint8)
    return packed


def unpack_group_codes(packed, k_group: int, g: int, bits: int):
    """Inverse of :func:`pack_group_codes` -> (sign, idx) uint8 [N, G, B]."""
    _check(k_group)
    n = packed.shape[0]
    fields_per_byte = 8 // k_group
    mask = (1 << k_group) - 1
    shifts = (k_group * jnp.arange(fields_per_byte, dtype=jnp.uint32))
    field = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    field = field.reshape(n, -1)[:, : g * bits]
    field = field.reshape(n, g, bits)  # [N, G, B]
    sign = (field >> (k_group - 1)).astype(jnp.uint8)
    idx = (field & ((1 << (k_group - 1)) - 1)).astype(jnp.uint8)
    return sign, idx
