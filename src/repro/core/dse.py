"""Design-space exploration for the LUT datapath (paper §3.2.2, Fig 11/14),
re-costed for two targets:

  * ``mux_density(K)`` — the paper's hardware model: a mux-tree LUT unit
    performs K MACs per lookup per cycle; area = table registers
    (2^(K-1)·LUT_BIT) + mux tree + the accumulation adder. Density K/area
    peaks at K=4 for INT-quantized tables and K≈5 for FP16 tables — the
    paper's Fig 11 result (constants calibrated to reproduce those optima).

  * ``mxu_cost(K)`` — our TPU realization: the lookup runs as a
    [M, G·E] × [G·E, N] matmul on the MXU, so lookup is NOT O(1) — it costs
    2^(K-1)/K MACs per original element. With INT8 tables (2× MXU rate) the
    compute-optimal K is ≤ 2; K=1 degenerates to the paper's bit-serial
    ADD baseline, K=4 keeps the paper's table shape. This shift of the DSE
    optimum (mux: K=4 → MXU: K=2) is the central hardware-adaptation
    finding (DESIGN.md §2); bench_dse.py sweeps and reports both.

Tile-shape DSE (Fig 14 analogue): ``tile_efficiency`` scores (M, N, K)
tiles by data movement per MAC — elongated-N tiles win because each table
entry is reused N times (Eq. 7-8).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# -- mux-hardware constants (arbitrary gate units, calibrated to Fig 11) ----
TABLE_BIT_AREA = 0.21     # per stored table bit
MUX_BIT_AREA = 0.12       # per mux-input bit
INT_ADDER_AREA = 24.0     # INT accumulate adder
FP_ADDER_AREA = 210.0     # FP16 accumulate adder
PRECOMP_ADDER_AREA = 16.0  # per precompute adder (conventional designs only)


def mux_density(k: int, *, lut_bits: int = 8, fp_accum: bool = False,
                symmetrized: bool = True, fused_precompute: bool = True) -> float:
    """MACs/cycle per unit area of a mux-LUT dot-product unit."""
    entries = (1 << (k - 1)) if symmetrized else (1 << k)
    table = entries * lut_bits * TABLE_BIT_AREA
    mux = max(entries - 1, 1) * lut_bits * MUX_BIT_AREA
    adder = FP_ADDER_AREA if fp_accum else INT_ADDER_AREA
    area = table + mux + adder
    if not fused_precompute:  # conventional: per-unit precompute adders
        area += entries * PRECOMP_ADDER_AREA
    return k / area


def mxu_cost(k: int, *, int8_tables: bool = True, w_bits: int = 2) -> Dict[str, float]:
    """Relative costs of the MXU realization per original weight element."""
    e = 1 << (k - 1)
    macs_per_elem = e / k                       # CW row expansion
    rate = 2.0 if int8_tables else 1.0          # int8 MXU runs 2x bf16
    compute = macs_per_elem / rate              # MXU-cycles per element
    table_bytes_per_elem = e / k * (1 if int8_tables else 4)
    precompute_adds_per_elem = e / k            # table build on the VPU
    decode_fields_per_elem = w_bits / k         # unpack work per element
    return {
        "k": k,
        "compute": compute,
        "table_bytes": table_bytes_per_elem,
        "precompute": precompute_adds_per_elem,
        "decode": decode_fields_per_elem,
        # single scalar for argmin: MXU time dominates; VPU work overlaps
        # but is tie-broken at 1% weight
        "score": compute + 0.01 * (precompute_adds_per_elem
                                   + decode_fields_per_elem),
    }


def best_k_mux(lut_bits: int = 8, fp_accum: bool = False,
               ks: Tuple[int, ...] = (1, 2, 3, 4, 5, 6, 7, 8)) -> int:
    return max(ks, key=lambda k: mux_density(k, lut_bits=lut_bits,
                                             fp_accum=fp_accum))


def best_k_mxu(int8_tables: bool = True,
               ks: Tuple[int, ...] = (1, 2, 3, 4, 5, 6, 7, 8)) -> int:
    return min(ks, key=lambda k: mxu_cost(k, int8_tables=int8_tables)["score"])


# -- tile-shape DSE (Fig 14 / Eq. 7-8) --------------------------------------

def tile_traffic(m: int, n: int, k_elems: int, *, k_group: int = 4,
                 w_bits: int = 2, lut_bits: int = 8, a_bits: int = 16) -> Dict[str, float]:
    """Bytes moved per tile and per MAC for an (M, N, K) LUT tile."""
    g = k_elems // k_group
    e = 1 << (k_group - 1)
    table = m * g * e * lut_bits / 8            # Eq. 7 (table side)
    weights = n * g * k_group * w_bits / 8      # Eq. 8 (packed codes)
    acts = m * k_elems * a_bits / 8             # if the table is built here
    out = m * n * 4
    macs = m * n * k_elems
    total = table + weights + out
    return {"table": table, "weights": weights, "acts": acts, "out": out,
            "bytes_per_mac": total / macs, "macs": macs}


def sweep_tiles(area: int = 512, k_group: int = 4, w_bits: int = 2):
    """All (M, N, K) with M·N·K == area (the paper's iso-area sweep)."""
    rows: List[Dict] = []
    for m in (1, 2, 4, 8, 16, 32):
        for n in (4, 8, 16, 32, 64, 128, 256):
            if area % (m * n):
                continue
            k = area // (m * n)
            if k % k_group or k < k_group:
                continue
            r = tile_traffic(m, n, k, k_group=k_group, w_bits=w_bits)
            r.update({"m": m, "n": n, "k": k})
            rows.append(r)
    return sorted(rows, key=lambda r: r["bytes_per_mac"])
