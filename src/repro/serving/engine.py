"""Device-resident continuous-batching decode engine.

The engine owns a fixed pool of ``max_batch`` decode slots backed by one
static-shape KV/SSM cache. Weights are the packed low-bit serving format
(``serve_quantized`` params): batched decode is exactly the mpGEMM regime
the paper targets — memory-bound GEMV-shaped ops where the 4–16x
weight-traffic cut pays off — so the engine loop must not squander the
kernel's win on host round-trips.

All per-token control state lives ON DEVICE in an :class:`EngineState`
pytree (per-slot ``pos``/``budget``/``last_tok``/``active``, per-slot
sampling params, the PRNG key, and the caches). Three jitted programs:

  * ``decode_chunk``: ``jax.lax.scan`` over N decode steps for the whole
    pool — per-slot active masking, on-device budget/max-seq/EOS stopping,
    on-device per-slot sampling — emitting a ``[N, B]`` token buffer. The
    host syncs ONCE per chunk (read tokens + liveness), not once per token.
  * ``prefill_chunk``: ONE fixed-``[1, C]``-shape program that writes a
    prompt chunk into a batch-1 slot-cache view at a dynamic cache offset
    (no per-length recompiles, no B× wasted full-batch forward per admit).
    The LM head of a prefill chunk is dead code (only caches are returned),
    so XLA drops the vocab projection entirely.
  * ``merge``: write the batch-1 slot caches back into the pool at the
    slot's batch index (per-leaf batch axes via ``kvcache.batch_axes``).

Admission leaves the LAST prompt token out of prefill: it becomes the
slot's ``last_tok`` at ``pos = len(prompt) - 1``, so the first generated
token falls out of the decode scan itself — admission costs zero host syncs
and zero sampling dispatches.

Admit/retire stay on host but only run at chunk boundaries, preserving
continuous-batching semantics: finished slots are refilled from the queue
without touching in-flight ones. Per-slot positions mean one program serves
ragged sequence lengths (attention masks by each slot's own valid length;
SSM state is position-free).

Known edges (documented, covered by tests):
  * a prompt longer than ``max_seq`` is truncated to its last
    ``max(1, max_seq - max_new_tokens)`` tokens (room to generate);
  * a prompt that already fills the cache (``len == max_seq``) yields no
    tokens (there is no cache position left to write the first one);
  * ``max_new_tokens <= 0`` completes immediately with no output;
  * slots that finish mid-chunk idle until the next chunk boundary (their
    compute is masked out, their state is reset at the next admit).
"""

from __future__ import annotations

import dataclasses
import queue
import time
import warnings
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig
from repro.models import api, kvcache
from repro.serving.sampler import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 32
    temperature: float = 0.0           # <= 0 -> greedy
    top_k: int = 0                     # 0 -> disabled
    top_p: float = 1.0                 # >= 1 -> disabled
    done: bool = False
    output: Optional[List[int]] = None


@dataclasses.dataclass
class EngineState:
    """Device-resident engine state (registered pytree; one leaf per field).

    All leaves are arrays: ``[B]`` per-slot control/sampling vectors, the
    PRNG key, and the full cache pytree. The decode scan threads the whole
    state through ``jax.lax.scan``; the host only reads it back at chunk
    boundaries.
    """
    pos: jax.Array          # [B] i32  next cache write position (= valid len)
    budget: jax.Array       # [B] i32  remaining new tokens
    last_tok: jax.Array     # [B] i32  next token to feed
    active: jax.Array       # [B] bool decoding live
    temperature: jax.Array  # [B] f32  per-slot sampling params
    top_k: jax.Array        # [B] i32
    top_p: jax.Array        # [B] f32
    key: jax.Array          # PRNG key
    caches: Any             # model cache pytree


jax.tree_util.register_dataclass(
    EngineState,
    data_fields=["pos", "budget", "last_tok", "active", "temperature",
                 "top_k", "top_p", "key", "caches"],
    meta_fields=[])


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 512, seed: int = 0, decode_chunk: int = 8,
                 prefill_chunk: int = 32, eos_id: Optional[int] = None,
                 tuning_cache: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.decode_chunk = max(1, decode_chunk)
        self.prefill_chunk = max(1, min(prefill_chunk, max_seq))
        self.eos_id = eos_id
        self._seed = seed
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.slots: List[Optional[Request]] = [None] * max_batch

        # persistent kernel-tuning cache: activates fusion="tuned" lookups
        # for every mpGEMM dispatched by this engine's jitted programs
        # (trace-time dict hits; populate via pretune() or bench_autotune)
        self.tuning_cache = None
        if tuning_cache is not None:
            from repro.core import autotune
            self.tuning_cache = autotune.configure(tuning_cache)

        # per-leaf batch axes of the cache pytree (shape-diff discovery:
        # hybrid stacks carry batch at axis 2, plain stacks at axis 1)
        c1 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, max_seq, dtype=jnp.float32))
        c2 = jax.eval_shape(
            lambda: api.init_cache(cfg, 2, max_seq, dtype=jnp.float32))
        self._axes = kvcache.batch_axes(c1, c2)
        # zero batch-1 slot caches: the prefill starting point for every
        # admit (a retiring request's state must never leak into its slot's
        # next occupant — SSM states are cumulative)
        self._zero_slot = api.init_cache(cfg, 1, max_seq, dtype=jnp.float32)

        # the decode carry (caches dominate it) is donated: without donation
        # every chunk dispatch copies the full [B, S] cache pytree just to
        # write the new state next to it — pure memory traffic that grows
        # with max_batch·max_seq and was a visible slice of per-chunk
        # latency at large decode_chunk settings
        self._decode = jax.jit(self._decode_chunk_impl, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_chunk_impl)
        self._merge = jax.jit(
            lambda caches, slot, i: kvcache.merge_batch(
                caches, slot, self._axes, i))

        self.reset(seed=seed)

    # -- lifecycle ----------------------------------------------------------
    def reset(self, seed: Optional[int] = None):
        """Clear queue/slots/state/counters; keep compiled programs."""
        if seed is None:
            seed = self._seed
        b = self.max_batch
        self.queue = queue.Queue()
        self.slots = [None] * b
        self.state = EngineState(
            pos=jnp.zeros(b, jnp.int32),
            budget=jnp.zeros(b, jnp.int32),
            last_tok=jnp.zeros(b, jnp.int32),
            active=jnp.zeros(b, bool),
            temperature=jnp.zeros(b, jnp.float32),
            top_k=jnp.zeros(b, jnp.int32),
            top_p=jnp.ones(b, jnp.float32),
            key=jax.random.key(seed),
            caches=api.init_cache(self.cfg, b, self.max_seq,
                                  dtype=jnp.float32))
        self.decode_syncs = 0       # host round-trips in the decode loop
        self.decode_tokens = 0      # tokens emitted by decode chunks
        self.prefill_dispatches = 0
        self.chunk_latencies: List[float] = []  # seconds per decode chunk

    # -- jitted programs ----------------------------------------------------
    def _prefill_chunk_impl(self, params, slot_caches, tokens, offset, valid):
        """Write one [1, C] prompt chunk into a batch-1 slot-cache view at
        cache offset ``offset``; ``valid`` <= C real tokens (right-pad)."""
        _, new_caches, _ = api.forward(
            params, {"tokens": tokens}, self.cfg, caches=slot_caches,
            cache_pos=offset, token_valid=jnp.reshape(valid, (1,)))
        return new_caches

    def _decode_chunk_impl(self, params, state):
        """N decode steps for the whole pool in one dispatch."""
        def step(st, _):
            key, sub = jax.random.split(st.key)
            logits, new_caches, _ = api.forward(
                params, {"tokens": st.last_tok[:, None]}, self.cfg,
                caches=st.caches, cache_pos=st.pos)
            nxt = sample(sub, logits[:, -1], temperature=st.temperature,
                         top_k=st.top_k, top_p=st.top_p)
            # emit iff live and the cache has room for this token
            can = st.active & (st.pos + 1 < self.max_seq)
            hit_cap = st.active & ~can
            budget = jnp.where(can, st.budget - 1,
                               jnp.where(hit_cap, 0, st.budget))
            active = can & (budget > 0)
            if self.eos_id is not None:
                active &= nxt != self.eos_id
            st = dataclasses.replace(
                st,
                pos=st.pos + can.astype(jnp.int32),
                budget=budget,
                last_tok=jnp.where(can, nxt, st.last_tok),
                active=active,
                key=key,
                caches=new_caches)
            return st, (nxt, can)

        state, (toks, valid) = jax.lax.scan(
            step, state, None, length=self.decode_chunk)
        return state, toks, valid  # toks/valid: [N, B]

    # -- host loop (chunk boundaries only) ----------------------------------
    def submit(self, req: Request):
        req.output = []
        self.queue.put(req)

    def _admit_one(self, i: int, req: Request):
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if prompt.size > self.max_seq:
            keep = max(1, self.max_seq - req.max_new_tokens)
            prompt = prompt[-keep:]
        plen = int(prompt.size)

        # chunked prefill of prompt[:-1] into a zeroed batch-1 slot view;
        # the last token is fed to the first decode step instead
        c = self.prefill_chunk
        slot_caches = self._zero_slot
        for j in range(0, plen - 1, c):
            vl = min(c, plen - 1 - j)
            buf = np.zeros((1, c), np.int32)
            buf[0, :vl] = prompt[j:j + vl]
            slot_caches = self._prefill(
                self.params, slot_caches, jnp.asarray(buf),
                np.int32(j), np.int32(vl))
            self.prefill_dispatches += 1

        st = self.state
        live = req.max_new_tokens > 0
        self.state = dataclasses.replace(
            st,
            pos=st.pos.at[i].set(plen - 1),
            budget=st.budget.at[i].set(req.max_new_tokens),
            last_tok=st.last_tok.at[i].set(int(prompt[-1])),
            active=st.active.at[i].set(live),
            temperature=st.temperature.at[i].set(float(req.temperature)),
            top_k=st.top_k.at[i].set(int(req.top_k)),
            top_p=st.top_p.at[i].set(float(req.top_p)),
            caches=self._merge(st.caches, slot_caches, np.int32(i)))
        if live:
            self.slots[i] = req
        else:
            req.done = True  # nothing to generate

    def _admit(self) -> int:
        n = 0
        for i in range(self.max_batch):
            if self.slots[i] is None and not self.queue.empty():
                self._admit_one(i, self.queue.get())
                n += 1
        return n

    def step(self) -> bool:
        """One chunk cycle: admit, decode N tokens/slot, retire."""
        admitted = self._admit()
        occupied = [i for i, r in enumerate(self.slots) if r is not None]
        if not occupied:
            return admitted > 0
        t0 = time.perf_counter()
        self.state, toks, valid = self._decode(self.params, self.state)
        toks, valid, alive = jax.device_get(
            (toks, valid, self.state.active))  # THE once-per-chunk sync
        self.decode_syncs += 1
        self.chunk_latencies.append(time.perf_counter() - t0)
        for n in range(toks.shape[0]):
            for i in occupied:
                if valid[n, i]:
                    self.slots[i].output.append(int(toks[n, i]))
                    self.decode_tokens += 1
        for i in occupied:
            if not alive[i]:
                self.slots[i].done = True
                self.slots[i] = None  # retire -> refillable next boundary
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while (any(s is not None for s in self.slots)
               or not self.queue.empty()):
            if not self.step():
                break
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("serving did not converge")
        return ticks

    # -- kernel autotuning --------------------------------------------------
    def pretune(self, *, repeats: int = 2, max_candidates: int = 4,
                verbose: bool = False) -> int:
        """Measure-tune every mpGEMM shape this engine dispatches.

        Decode steps run M = max_batch activations per projection; prefill
        chunks run M = prefill_chunk. Tunes each (M, packed-weight shape)
        pair missing from the tuning cache and persists the cache, so a
        subsequent trace with ``fusion="tuned"`` resolves every dispatch
        from measured data (trace-time dict hit, sub-ms). Only meaningful
        for ``mpgemm_mode="lut_pallas"`` — the other modes have no block
        knobs to tune.
        """
        from repro.core import autotune
        cache = self.tuning_cache or autotune.get_active()
        if cache is None:
            raise ValueError("pretune() needs a tuning cache — construct "
                             "the engine with tuning_cache=<path>")
        q = self.cfg.quant or {}
        if q.get("mpgemm_mode") != "lut_pallas":
            warnings.warn("pretune() is a no-op for mpgemm_mode="
                          f"{q.get('mpgemm_mode')!r} (no kernel knobs)")
            return 0
        n = autotune.pretune_params(
            self.params, [self.max_batch, self.prefill_chunk], cache=cache,
            table_quant=q.get("table_quant", "per_row"), repeats=repeats,
            max_candidates=max_candidates, verbose=verbose)
        if cache.path is not None:
            cache.save()
        return n

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        lat = sorted(self.chunk_latencies)
        pct = (lambda p: lat[min(len(lat) - 1, int(p * len(lat)))]
               if lat else 0.0)
        toks = max(1, self.decode_tokens)
        decode_s = sum(self.chunk_latencies)
        return {
            "decode_chunk": self.decode_chunk,
            "prefill_chunk": self.prefill_chunk,
            "decode_syncs": self.decode_syncs,
            "decode_tokens": self.decode_tokens,
            "host_syncs_per_token": self.decode_syncs / toks,
            "prefill_dispatches": self.prefill_dispatches,
            "p50_chunk_ms": pct(0.50) * 1e3,
            "p95_chunk_ms": pct(0.95) * 1e3,
            # decode-only throughput: excludes prefill/admit/compile, so it
            # is the number that isolates a decode-chunk latency cliff
            "decode_tok_s": self.decode_tokens / decode_s if decode_s else 0.0,
        }
