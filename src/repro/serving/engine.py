"""Device-resident continuous-batching decode engine.

The engine owns a fixed pool of ``max_batch`` decode slots backed by one
static-shape KV/SSM cache. Weights are the packed low-bit serving format
(``serve_quantized`` params): batched decode is exactly the mpGEMM regime
the paper targets — memory-bound GEMV-shaped ops where the 4–16x
weight-traffic cut pays off — so the engine loop must not squander the
kernel's win on host round-trips.

All per-token control state lives ON DEVICE in an :class:`EngineState`
pytree (per-slot ``pos``/``budget``/``last_tok``/``active``, per-slot
sampling params, the PRNG key, and the caches). Three jitted programs:

  * ``decode_chunk``: ``jax.lax.scan`` over N decode steps for the whole
    pool — per-slot active masking, on-device budget/max-seq/EOS stopping,
    on-device per-slot sampling — emitting a ``[N, B]`` token buffer. The
    host syncs ONCE per chunk (read tokens + liveness), not once per token.
  * ``prefill_chunk``: ONE fixed-``[1, C]``-shape program that writes a
    prompt chunk into a batch-1 slot-cache view at a dynamic cache offset
    (no per-length recompiles, no B× wasted full-batch forward per admit).
    The LM head of a prefill chunk is dead code (only caches are returned),
    so XLA drops the vocab projection entirely.
  * ``merge``: write the batch-1 slot caches back into the pool at the
    slot's batch index (per-leaf batch axes via ``kvcache.batch_axes``).

Admission leaves the LAST prompt token out of prefill: it becomes the
slot's ``last_tok`` at ``pos = len(prompt) - 1``, so the first generated
token falls out of the decode scan itself — admission costs zero host syncs
and zero sampling dispatches.

Admit/retire stay on host but only run at chunk boundaries, preserving
continuous-batching semantics: finished slots are refilled from the queue
without touching in-flight ones. Per-slot positions mean one program serves
ragged sequence lengths (attention masks by each slot's own valid length;
SSM state is position-free).

Known edges (documented, covered by tests):
  * a prompt longer than ``max_seq`` is truncated to its last
    ``max(1, max_seq - max_new_tokens)`` tokens (room to generate);
  * a prompt that already fills the cache (``len == max_seq``) yields no
    tokens (there is no cache position left to write the first one);
  * ``max_new_tokens <= 0`` completes immediately with no output;
  * slots that finish mid-chunk idle until the next chunk boundary (their
    compute is masked out, their state is reset at the next admit).
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchConfig
from repro.distributed import sharding as shrules
from repro.distributed.sharding import AxisPlan, plan_scope
from repro.models import api, kvcache
from repro.obs import dispatch as dispatch_obs
from repro.obs.metrics import MetricsRegistry, export_stats
from repro.obs.trace import Tracer
from repro.serving import blockpool, decoding
from repro.serving.sampler import mask_logits, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 32
    temperature: float = 0.0           # <= 0 -> greedy
    top_k: int = 0                     # 0 -> disabled
    top_p: float = 1.0                 # >= 1 -> disabled
    decoding: str = "greedy"           # greedy | sample | beam[:W] | spec
    done: bool = False
    output: Optional[List[int]] = None
    beams: Optional[List[Tuple[List[int], float]]] = None  # beam mode: all
    # retired hypotheses as (tokens, length-normalized score), best first
    spec_stats: Optional[Dict[str, int]] = None  # spec mode: verify_steps /
    # accepted_draft_tokens for this request


@dataclasses.dataclass
class EngineState:
    """Device-resident engine state (registered pytree; one leaf per field).

    All leaves are arrays: ``[B]`` per-slot control/sampling vectors, the
    PRNG key, and the full cache pytree. The decode scan threads the whole
    state through ``jax.lax.scan``; the host only reads it back at chunk
    boundaries.
    """
    pos: jax.Array          # [B] i32  next cache write position (= valid len)
    budget: jax.Array       # [B] i32  remaining new tokens
    last_tok: jax.Array     # [B] i32  next token to feed
    active: jax.Array       # [B] bool decoding live
    temperature: jax.Array  # [B] f32  per-slot sampling params
    top_k: jax.Array        # [B] i32
    top_p: jax.Array        # [B] f32
    mode: jax.Array         # [B] i32  decoding kind (decoding.NORMAL/BEAM/SPEC)
    beam_group: jax.Array   # [B] i32  beam-group id (leader slot idx); -1 none
    beam_score: jax.Array   # [B] f32  cumulative hypothesis log-prob
    spec_steps: jax.Array   # [B] i32  verify rounds run by this occupant
    spec_accepted: jax.Array  # [B] i32 draft tokens accepted+emitted
    key: jax.Array          # PRNG key
    page_table: jax.Array   # [B, blocks_per_slot] i32 pool block per logical
                            # page (paged mode; [B, 1] zeros when dense)
    caches: Any             # model cache pytree


jax.tree_util.register_dataclass(
    EngineState,
    data_fields=["pos", "budget", "last_tok", "active", "temperature",
                 "top_k", "top_p", "mode", "beam_group", "beam_score",
                 "spec_steps", "spec_accepted", "key", "page_table",
                 "caches"],
    meta_fields=[])


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 512, seed: int = 0, decode_chunk: int = 8,
                 prefill_chunk: int = 32, eos_id: Optional[int] = None,
                 tuning_cache: Optional[str] = None,
                 cache_block_size: Optional[int] = None,
                 num_cache_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 kv_cache_dtype: Optional[str] = None,
                 plan: Optional[AxisPlan] = None,
                 spec_k: int = 4,
                 spec_draft_planes: Optional[int] = None,
                 beam_length_alpha: float = 0.6,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        # ---- telemetry (repro.obs) ---------------------------------------
        # The tracer records request-lifecycle spans with host timestamps
        # taken ONLY at sync/dispatch points that already exist — telemetry
        # adds zero device round-trips (host_syncs_per_token is invariant;
        # benchmarks/bench_telemetry.py gates the tok/s overhead). A None
        # tracer costs one `is not None` check per site. The metrics
        # registry always exists: its bounded-reservoir histograms ARE the
        # engine's latency/occupancy storage (O(reservoir) however long the
        # engine lives, unlike the unbounded lists they replaced).
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if plan is not None:
            # per-host series labels so mesh'd snapshots merge cleanly
            self.metrics.set_common_labels(
                host=str(jax.process_index()),
                mesh="x".join(str(s) for s in plan.mesh.devices.shape))
        self._h_chunk_s = self.metrics.histogram(
            "engine_decode_chunk_seconds",
            help="wall seconds per decode-chunk dispatch (sync to sync)",
            unit="s")
        self._h_occupancy = self.metrics.histogram(
            "engine_slot_occupancy_ratio",
            help="occupied slots / max_batch, sampled once per chunk")
        self._h_prefill_s = self.metrics.histogram(
            "engine_prefill_chunk_seconds",
            help="wall seconds per prefill-chunk dispatch", unit="s")
        # Tensor/data-parallel serving: ``plan`` shards the packed weights
        # (named_sharding_tree), the engine state and the cache pool across
        # the plan's mesh, and every jitted program traces inside
        # ``plan_scope`` so the models' logical-axis shard() hooks fire.
        # ``plan=None`` is the single-device default — identical to a 1x1
        # mesh plan, where every constraint resolves to replication.
        self.plan = plan
        if plan is not None:
            params = jax.device_put(
                params, shrules.named_sharding_tree(params, plan))
        elif (cfg.quant and jax.default_backend() == "cpu"
              and cfg.quant.get("mpgemm_mode", "lut_xla") == "lut_xla"
              and cfg.quant.get("store") is None
              and spec_draft_planes is None):
            # (self-speculation pins the packed store: the draft view is a
            # plane slice of the packed buffer, which the CW expansion
            # destroys — see plane_sliced_params)
            # Single-device CPU serving: the XLA LUT path has no hardware
            # lookup unit, so a packed store forces a packed->CW expansion
            # inside every decode step. Hoist it: convert once to the
            # offline-CW store (bit-exact, same lut_xla epilogue). Pin
            # quant["store"]="packed" to keep packed planes resident.
            from repro.models.quantized import to_cw_params
            params = to_cw_params(params)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.decode_chunk = max(1, decode_chunk)
        self.prefill_chunk = max(1, min(prefill_chunk, max_seq))
        self.eos_id = eos_id
        self._seed = seed
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch

        # persistent kernel-tuning cache: activates fusion="tuned" lookups
        # for every mpGEMM dispatched by this engine's jitted programs
        # (trace-time dict hits; populate via pretune() or bench_autotune)
        self.tuning_cache = None
        if tuning_cache is not None:
            from repro.core import autotune
            self.tuning_cache = autotune.configure(tuning_cache)

        kv_dt = kv_cache_dtype or cfg.kv_cache_dtype
        self._cache_dtype = "int8" if kv_dt == "int8" else jnp.float32

        # per-leaf batch axes of the cache pytree (shape-diff discovery:
        # hybrid stacks carry batch at axis 2, plain stacks at axis 1)
        c1 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, max_seq, dtype=self._cache_dtype))
        c2 = jax.eval_shape(
            lambda: api.init_cache(cfg, 2, max_seq, dtype=self._cache_dtype))
        self._axes = kvcache.batch_axes(c1, c2)
        # per-leaf sequence axes (same probe trick, varying s_cache): leaves
        # with no sequence axis — SSM conv/ssm state, image/cross KV — are
        # O(1) per slot and stay dense slot-indexed even in paged mode
        s1 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, 16, dtype=self._cache_dtype))
        s2 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, 32, dtype=self._cache_dtype))
        self._seq_axes = kvcache.seq_axes(s1, s2)
        # self-speculation rewrites cache POSITIONS (draft writes are
        # overwritten by the verify forward, rejected suffixes by the next
        # round) — only valid when every cache leaf is positional. SSM /
        # conv state is cumulative and cannot rewind a rejected token.
        self._spec_ok = all(sax >= 0
                            for sax in jax.tree.leaves(self._seq_axes))

        # ---- decoding-mode zoo (serving/decoding.py) ----------------------
        self.spec_k = max(1, int(spec_k))
        self.spec_draft_planes = spec_draft_planes
        self.beam_length_alpha = float(beam_length_alpha)
        self.draft_params = None
        self.draft_extra_hbm_bytes = 0
        if spec_draft_planes is not None:
            from repro.models import quantized as qz
            self.draft_params = qz.plane_sliced_params(
                self.params, int(spec_draft_planes))
            # acceptance probe: the draft view must share every buffer with
            # the target by identity (zero extra weight HBM)
            self.draft_extra_hbm_bytes = qz.extra_hbm_bytes(
                self.draft_params, self.params)
        # compiled decode variants keyed by the pool's static mode mix
        # (has_beam, has_spec); (False, False) is the legacy self._decode
        self._decode_variants: Dict[Tuple[bool, bool], Any] = {}
        # zero batch-1 slot caches: the prefill starting point for every
        # admit (a retiring request's state must never leak into its slot's
        # next occupant — SSM states are cumulative)
        self._zero_slot = api.init_cache(cfg, 1, max_seq,
                                         dtype=self._cache_dtype)

        # ---- block-paged cache pool (optional) ----------------------------
        self.paged = cache_block_size is not None
        self.prefix_caching = bool(prefix_cache) and self.paged
        self._alloc: Optional[blockpool.BlockAllocator] = None
        self._prefix: Optional[blockpool.PrefixCache] = None
        if self.paged:
            bs = int(cache_block_size)
            if bs < 1 or max_seq % bs != 0:
                raise ValueError(
                    f"cache_block_size={bs} must be >= 1 and divide "
                    f"max_seq={max_seq}: the gathered paged view must be "
                    f"exactly max_seq long for bit-exact parity with dense")
            self.cache_block_size = bs
            self.blocks_per_slot = max_seq // bs
            if num_cache_blocks is None:
                # dense-equivalent capacity: every slot can hold max_seq,
                # plus the reserved null block
                num_cache_blocks = max_batch * self.blocks_per_slot + 1
            if num_cache_blocks < self.blocks_per_slot + 1:
                raise ValueError(
                    f"num_cache_blocks={num_cache_blocks} cannot hold even "
                    f"one max_seq={max_seq} request at block size {bs} "
                    f"(need >= {self.blocks_per_slot + 1} incl. null block)")
            self.num_cache_blocks = int(num_cache_blocks)

            # pooled leaves must carry (batch, seq) adjacently so that
            # init_cache(cfg, num_blocks, block_size) IS the pool ctor
            def _check(path, bax, sax):
                if sax >= 0 and sax != bax + 1:
                    raise ValueError(
                        f"cannot page cache leaf at "
                        f"{jax.tree_util.keystr(path)!r}: sequence axis "
                        f"{sax} is not adjacent to batch axis {bax}")
                return sax >= 0
            self._pooled = jax.tree_util.tree_map_with_path(
                _check, self._axes, self._seq_axes)
            pooled_leaves = jax.tree.leaves(self._pooled)
            self.has_pooled = any(pooled_leaves)
            self._all_pooled = all(pooled_leaves)
            if self.prefix_caching and not all(jax.tree.leaves(self._pooled)):
                warnings.warn(
                    "prefix caching needs every cache leaf paged; family="
                    f"{cfg.family!r} holds slot-resident state (SSM/cross "
                    "KV) that cannot fan out by block reference — disabled")
                self.prefix_caching = False

            nb_total = self.num_cache_blocks

            def _build_paged():
                # one jitted builder selecting pool vs dense per leaf: XLA
                # DCEs the unused half, so SSM state is never allocated at
                # batch=num_blocks nor attention KV at [B, max_seq] density
                pool = api.init_cache(cfg, nb_total, bs,
                                      dtype=self._cache_dtype)
                dense = api.init_cache(cfg, max_batch, max_seq,
                                       dtype=self._cache_dtype)
                return jax.tree.map(
                    lambda p, d, pooled: p if pooled else d,
                    pool, dense, self._pooled)

            self._build_paged = jax.jit(_build_paged)
            # prefill view: pool leaves ride through whole; unpooled leaves
            # are a batch-1 slot view (donated through the chunk loop)
            self._prefill_paged = jax.jit(self._paged_prefill_impl,
                                          donate_argnums=(1,))
            self._copy_block = jax.jit(self._copy_block_impl,
                                       donate_argnums=(0,))

        # the decode carry (caches dominate it) is donated: without donation
        # every chunk dispatch copies the full [B, S] cache pytree just to
        # write the new state next to it — pure memory traffic that grows
        # with max_batch·max_seq and was a visible slice of per-chunk
        # latency at large decode_chunk settings
        self._decode = jax.jit(self._decode_chunk_impl, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_chunk_impl)
        # beam admission fork: copy one slot's unpooled cache rows to another
        self._fork_slot = jax.jit(self._fork_slot_impl, donate_argnums=(0,))
        self._merge = jax.jit(
            lambda caches, slot, i: kvcache.merge_batch(
                caches, slot, self._axes, i))

        self.reset(seed=seed)

    # -- lifecycle ----------------------------------------------------------
    def reset(self, seed: Optional[int] = None):
        """Clear queue/slots/state/counters; keep compiled programs."""
        if seed is None:
            seed = self._seed
        b = self.max_batch
        self.queue = deque()
        self.slots = [None] * b
        if self.paged:
            self._alloc = blockpool.BlockAllocator(self.num_cache_blocks,
                                                   metrics=self.metrics)
            self._prefix = (blockpool.PrefixCache(self._alloc,
                                                  metrics=self.metrics)
                            if self.prefix_caching else None)
            self._pending_keys: set = set()  # divergence entries whose last
            # position is unwritten until the origin's first decode chunk
            self._slot_blocks: List[List[int]] = [[] for _ in range(b)]
            caches = self._build_paged()
            page_table = jnp.zeros((b, self.blocks_per_slot), jnp.int32)
        else:
            caches = api.init_cache(self.cfg, b, self.max_seq,
                                    dtype=self._cache_dtype)
            page_table = jnp.zeros((b, 1), jnp.int32)
        self.state = EngineState(
            pos=jnp.zeros(b, jnp.int32),
            budget=jnp.zeros(b, jnp.int32),
            last_tok=jnp.zeros(b, jnp.int32),
            active=jnp.zeros(b, bool),
            temperature=jnp.zeros(b, jnp.float32),
            top_k=jnp.zeros(b, jnp.int32),
            top_p=jnp.ones(b, jnp.float32),
            mode=jnp.zeros(b, jnp.int32),
            beam_group=jnp.full(b, -1, jnp.int32),
            beam_score=jnp.zeros(b, jnp.float32),
            spec_steps=jnp.zeros(b, jnp.int32),
            spec_accepted=jnp.zeros(b, jnp.int32),
            key=jax.random.key(seed),
            page_table=page_table,
            caches=caches)
        if self.plan is not None:
            self.state = jax.device_put(
                self.state, self._engine_state_shardings(self.state))
        self.decode_syncs = 0       # host round-trips in the decode loop
        self.decode_tokens = 0      # tokens emitted by decode chunks
        self.prefill_dispatches = 0
        # per-chunk latency/occupancy history lives in bounded-reservoir
        # histograms (engine_decode_chunk_seconds etc.), not python lists:
        # memory stays O(reservoir) however long the engine serves
        self._h_chunk_s.reset()
        self._h_occupancy.reset()
        self._h_prefill_s.reset()
        self.prefill_s = 0.0        # wall seconds spent in prefill dispatch
        self.prefill_tokens = 0     # prompt tokens actually prefilled
        self.prefill_tokens_reused = 0  # prompt tokens served from shared
        # blocks (prefix cache hits) instead of being re-prefilled
        self.admit_attempts = 0
        self.admit_blocked = 0      # admissions deferred for lack of blocks
        self.peak_active_slots = 0
        # decoding-mode bookkeeping (host mirrors of per-slot device state)
        self._slot_kind: List[int] = [decoding.NORMAL] * b
        self._beam_hist: List[List[int]] = [[] for _ in range(b)]
        self._beam_groups: Dict[int, Dict[str, Any]] = {}  # leader -> group
        self.spec_verify_steps = 0      # totals over retired spec requests
        self.spec_accepted_tokens = 0

    def _engine_state_shardings(self, state: EngineState) -> EngineState:
        """NamedSharding pytree for the engine state under ``self.plan``.

        Per-slot control vectors and the DENSE cache batch dim shard over
        the plan's batch axes; attention KV heads (dim seq+1) and SSM
        feature dims (dim batch+1) shard over the model axis, matching the
        column-parallel projections that produce them. Paged POOL leaves
        keep their block dim replicated: page tables index the global pool,
        so any slot may reference any block — sharding blocks over data
        would turn every page gather into a cross-shard collective. All of
        this is layout-only (GSPMD), so every fallback is replication, not
        an error."""
        plan = self.plan
        mesh = plan.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        batch_ax = plan.resolve("batch")
        model_ax = plan.resolve("model")

        def ns(shape, phys):
            return NamedSharding(mesh, P(*shrules.resolve_physical_spec(
                shape, phys, sizes)))

        def vec(x):
            return ns(x.shape, (batch_ax,) + (None,) * (x.ndim - 1))

        pooled = (self._pooled if self.paged
                  else jax.tree.map(lambda _: False, self._axes))

        def cache_leaf(c, bax, sax, is_pooled):
            phys = [None] * c.ndim
            if not is_pooled:
                phys[bax] = batch_ax
            feat = (sax + 1) if sax >= 0 else (bax + 1)
            if feat < c.ndim and phys[feat] is None:
                phys[feat] = model_ax
            return ns(c.shape, tuple(phys))

        caches_sh = jax.tree.map(cache_leaf, state.caches, self._axes,
                                 self._seq_axes, pooled)
        rep = NamedSharding(mesh, P())
        return EngineState(
            pos=vec(state.pos), budget=vec(state.budget),
            last_tok=vec(state.last_tok), active=vec(state.active),
            temperature=vec(state.temperature), top_k=vec(state.top_k),
            top_p=vec(state.top_p), mode=vec(state.mode),
            beam_group=vec(state.beam_group),
            beam_score=vec(state.beam_score),
            spec_steps=vec(state.spec_steps),
            spec_accepted=vec(state.spec_accepted), key=rep,
            page_table=vec(state.page_table), caches=caches_sh)

    # -- jitted programs ----------------------------------------------------
    def _prefill_chunk_impl(self, params, slot_caches, tokens, offset, valid):
        """Write one [1, C] prompt chunk into a batch-1 slot-cache view at
        cache offset ``offset``; ``valid`` <= C real tokens (right-pad)."""
        with plan_scope(self.plan):
            _, new_caches, _ = api.forward(
                params, {"tokens": tokens}, self.cfg, caches=slot_caches,
                cache_pos=offset, token_valid=jnp.reshape(valid, (1,)))
        return new_caches

    def _paged_prefill_impl(self, params, view_caches, tokens, offset, valid,
                            page_row):
        """One [1, C] prompt chunk written straight into the pool: pooled
        leaves scatter through the slot's page-table row ``page_row``
        ([1, blocks_per_slot]); unpooled (SSM/cross) leaves ride along as a
        batch-1 slot view. The whole view is donated through the chunk loop,
        so pool pages are updated in place across chunks."""
        with plan_scope(self.plan):
            _, new_caches, _ = api.forward(
                params, {"tokens": tokens}, self.cfg, caches=view_caches,
                cache_pos=offset, token_valid=jnp.reshape(valid, (1,)),
                page_table=page_row)
        return new_caches

    def _copy_block_impl(self, caches, src, dst):
        """Copy-on-write: clone pool block ``src`` into ``dst`` on every
        pooled leaf (unpooled leaves pass through untouched)."""
        def one(c, bax, sax):
            if sax < 0:
                return c
            blk = jax.lax.dynamic_index_in_dim(c, src, axis=bax,
                                               keepdims=True)
            return jax.lax.dynamic_update_slice_in_dim(c, blk, dst, axis=bax)
        return jax.tree.map(one, caches, self._axes, self._seq_axes)

    def _fork_slot_impl(self, caches, src, dst):
        """Beam admission fork: copy slot ``src``'s cache row to ``dst`` on
        every slot-resident (unpooled) leaf. Pooled leaves pass through —
        the member's page-table row handles those (shared prefix blocks by
        reference, private blocks by ``_copy_block``)."""
        pooled = (self._pooled if self.paged
                  else jax.tree.map(lambda _: False, self._axes))

        def one(c, bax, is_pooled):
            if is_pooled:
                return c
            row = jax.lax.dynamic_index_in_dim(c, src, axis=bax,
                                               keepdims=True)
            return jax.lax.dynamic_update_slice_in_dim(c, row, dst, axis=bax)
        return jax.tree.map(one, caches, self._axes, pooled)

    def _beam_fork_caches(self, caches, parent, page_table, do_copy):
        """In-scan beam reassignment: slot ``b`` adopts ``parent[b]``'s
        hypothesis state. Runs AFTER the step's forward, so the adopted
        content includes the parent's freshly written position.

        Unpooled leaves: batch gather by ``parent`` (identity rows for
        non-forking slots). Pooled leaves: the slot's page-table row is
        immutable inside the scan, so the fork copies block CONTENT from
        the parent's blocks into the slot's own blocks. Duplicate
        destinations are safe by construction: group members share
        identical prefix rows (those writes are value-identical
        self-copies), post-divergence blocks are private per slot, and
        non-forking slots are routed to the never-read null block 0.
        """
        pooled = (self._pooled if self.paged
                  else jax.tree.map(lambda _: False, self._axes))
        bsz = parent.shape[0]

        def one(c, bax, is_pooled):
            cm = jnp.moveaxis(c, bax, 0)
            if is_pooled:
                src_rows = page_table[parent].reshape(-1)      # [B*nbs]
                dst_rows = jnp.where(do_copy[:, None], page_table,
                                     0).reshape(-1)
                cm = cm.at[dst_rows].set(cm[src_rows])
            else:
                cm = cm[parent]
            return jnp.moveaxis(cm, 0, bax)
        del bsz
        return jax.tree.map(one, caches, self._axes, pooled)

    def _get_decode(self, has_beam: bool, has_spec: bool):
        """Compiled decode-chunk program for a pool mode mix. The
        (False, False) mix is the legacy two-arg ``self._decode``; the
        others share ``_decode_general_impl`` with the mode flags baked in
        as trace-time statics (signature: (params, draft_params, state))."""
        key = (has_beam, has_spec)
        fn = self._decode_variants.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(self._decode_general_impl,
                                           has_beam=has_beam,
                                           has_spec=has_spec),
                         donate_argnums=(2,))
            self._decode_variants[key] = fn
        return fn

    def _decode_general_impl(self, params, draft_params, state, *,
                             has_beam: bool, has_spec: bool):
        """Decoding-mode-zoo decode chunk: N scan steps over the pool with
        per-slot NORMAL / BEAM / SPEC behaviour in one jitted program.

        Emissions are ``[N, B, S_e]`` (``S_e = spec_k + 1`` when the pool
        holds spec slots, else 1) plus a ``[N, B]`` parent map for beam
        hypothesis reconstruction on the host.

        Speculative step anatomy (spec slots; every other slot rides along
        emitting at most its position-0 token):
          1. draft K tokens autoregressively with the plane-sliced view,
             writing PROVISIONAL KV at pos..pos+K-1;
          2. ONE s=K+1 target forward over [last_tok, d_0..d_{K-1}]
             re-writes pos..pos+K with target KV (the draft writes are
             fully overwritten — rejected positions hold invisible values
             that the next round re-writes before any read reaches them);
          3. accept the longest agreeing prefix (argmax agreement for
             greedy slots — bit-exact with plain greedy — or Leviathan
             rejection sampling), emit the replacement/bonus token, and
             advance ``pos`` by the emission count.
        """
        paged_kw = ({"page_table": state.page_table} if self.paged else {})
        k_spec = self.spec_k
        s_e = (k_spec + 1) if has_spec else 1
        bsz = self.max_batch
        self_idx = jnp.arange(bsz, dtype=jnp.int32)

        def step(st, _):
            key, k_draft, k_accept, k_sample = jax.random.split(st.key, 4)
            greedy = st.temperature <= 0.0
            is_spec = st.mode == decoding.SPEC
            is_beam = st.mode == decoding.BEAM

            if has_spec:
                # ---- 1. draft rollout (sliced-plane view) ---------------
                caches = st.caches
                last, dpos = st.last_tok, st.pos
                dkeys = jax.random.split(k_draft, k_spec)
                d_toks, d_masked = [], []
                for j in range(k_spec):
                    dl, caches, _ = api.forward(
                        draft_params, {"tokens": last[:, None]}, self.cfg,
                        caches=caches, cache_pos=dpos, **paged_kw)
                    dl = dl[:, -1]
                    ml = mask_logits(dl, temperature=st.temperature,
                                     top_k=st.top_k, top_p=st.top_p)
                    d = jnp.where(
                        greedy,
                        jnp.argmax(dl, axis=-1).astype(jnp.int32),
                        jax.random.categorical(dkeys[j], ml,
                                               axis=-1).astype(jnp.int32))
                    d_toks.append(d)
                    d_masked.append(ml)
                    last, dpos = d, dpos + 1
                d_toks = jnp.stack(d_toks, axis=1)          # [B, K]
                q_logits = jnp.stack(d_masked, axis=1)      # [B, K, V]

                # ---- 2. single verify forward (overwrites draft KV) -----
                verify_in = jnp.concatenate(
                    [st.last_tok[:, None], d_toks], axis=1)  # [B, K+1]
                vlogits, new_caches, _ = api.forward(
                    params, {"tokens": verify_in}, self.cfg,
                    caches=caches, cache_pos=st.pos, **paged_kw)
                logits1 = vlogits[:, 0]  # == the s=1 forward's logits
                tgt_raw_argmax = jnp.argmax(vlogits,
                                            axis=-1).astype(jnp.int32)
                p_logits = jnp.stack(
                    [mask_logits(vlogits[:, j],
                                 temperature=st.temperature,
                                 top_k=st.top_k, top_p=st.top_p)
                     for j in range(k_spec + 1)], axis=1)
                accept, repl, bonus = decoding.speculative_accept(
                    k_accept, d_toks, q_logits, p_logits, tgt_raw_argmax,
                    greedy)
            else:
                logits, new_caches, _ = api.forward(
                    params, {"tokens": st.last_tok[:, None]}, self.cfg,
                    caches=st.caches, cache_pos=st.pos, **paged_kw)
                logits1 = logits[:, -1]

            # ---- position-0 token per mode ------------------------------
            nxt_norm = sample(k_sample, logits1, temperature=st.temperature,
                              top_k=st.top_k, top_p=st.top_p)
            parent = self_idx
            beam_score = st.beam_score
            if has_beam:
                logp = jax.nn.log_softmax(logits1.astype(jnp.float32),
                                          axis=-1)
                live_beam = is_beam & st.active
                parent, btok, beam_score = decoding.beam_select(
                    st.beam_score, logp, live_beam, st.beam_group)
                new_caches = self._beam_fork_caches(
                    new_caches, parent, st.page_table, live_beam)
                tok0_ride = jnp.where(is_beam, btok, nxt_norm)
            else:
                tok0_ride = nxt_norm

            # ---- emission chain -----------------------------------------
            toks_l, valid_l = [], []
            cum = jnp.ones(bsz, bool)
            prior_eos = jnp.zeros(bsz, bool)
            n_emit = jnp.zeros(bsz, jnp.int32)
            acc_emitted = jnp.zeros(bsz, jnp.int32)
            for j in range(s_e):
                if has_spec:
                    if j < k_spec:
                        tok_j = jnp.where(accept[:, j], d_toks[:, j],
                                          repl[:, j])
                    else:
                        tok_j = bonus
                    if j == 0:
                        tok_j = jnp.where(is_spec, tok_j, tok0_ride)
                else:
                    tok_j = tok0_ride
                allow = (cum & st.active & (st.pos + 1 + j < self.max_seq)
                         & (st.budget > j) & ~prior_eos)
                if j > 0:
                    allow &= is_spec
                if self.eos_id is not None:
                    prior_eos = prior_eos | (allow & (tok_j == self.eos_id))
                toks_l.append(tok_j)
                valid_l.append(allow)
                n_emit = n_emit + allow.astype(jnp.int32)
                if has_spec and j < k_spec:
                    acc_emitted = acc_emitted + (
                        allow & accept[:, j] & is_spec).astype(jnp.int32)
                    cum = cum & accept[:, j]
            toks_m = jnp.stack(toks_l, axis=1)    # [B, S_e]
            valid_m = jnp.stack(valid_l, axis=1)  # [B, S_e]

            # ---- slot state update --------------------------------------
            emitted = n_emit > 0
            last_idx = jnp.clip(n_emit - 1, 0, s_e - 1)
            last_emitted = jnp.take_along_axis(
                toks_m, last_idx[:, None], axis=1)[:, 0]
            new_last = jnp.where(emitted, last_emitted, st.last_tok)
            new_pos = st.pos + n_emit
            hit_cap = st.active & (st.pos + 1 >= self.max_seq)
            new_budget = jnp.where(hit_cap, 0, st.budget - n_emit)
            new_active = st.active & emitted & (new_budget > 0) & ~prior_eos

            ran_spec = is_spec & st.active & emitted
            st = dataclasses.replace(
                st,
                pos=new_pos,
                budget=new_budget,
                last_tok=new_last,
                active=new_active,
                beam_score=beam_score,
                spec_steps=st.spec_steps + ran_spec.astype(jnp.int32),
                spec_accepted=st.spec_accepted + jnp.where(ran_spec,
                                                           acc_emitted, 0),
                key=key,
                caches=new_caches)
            return st, (toks_m, valid_m, parent)

        with plan_scope(self.plan):
            state, (toks, valid, parent) = jax.lax.scan(
                step, state, None, length=self.decode_chunk)
        return state, toks, valid, parent  # [N, B, S_e], [N, B]

    def _decode_chunk_impl(self, params, state):
        """N decode steps for the whole pool in one dispatch."""
        # the page table is closed over per chunk, not threaded through the
        # scan carry: no decode step ever remaps pages
        paged_kw = ({"page_table": state.page_table} if self.paged else {})

        def step(st, _):
            key, sub = jax.random.split(st.key)
            logits, new_caches, _ = api.forward(
                params, {"tokens": st.last_tok[:, None]}, self.cfg,
                caches=st.caches, cache_pos=st.pos, **paged_kw)
            nxt = sample(sub, logits[:, -1], temperature=st.temperature,
                         top_k=st.top_k, top_p=st.top_p)
            # emit iff live and the cache has room for this token
            can = st.active & (st.pos + 1 < self.max_seq)
            hit_cap = st.active & ~can
            budget = jnp.where(can, st.budget - 1,
                               jnp.where(hit_cap, 0, st.budget))
            active = can & (budget > 0)
            if self.eos_id is not None:
                active &= nxt != self.eos_id
            st = dataclasses.replace(
                st,
                pos=st.pos + can.astype(jnp.int32),
                budget=budget,
                last_tok=jnp.where(can, nxt, st.last_tok),
                active=active,
                key=key,
                caches=new_caches)
            return st, (nxt, can)

        with plan_scope(self.plan):
            state, (toks, valid) = jax.lax.scan(
                step, state, None, length=self.decode_chunk)
        return state, toks, valid  # toks/valid: [N, B]

    # -- host loop (chunk boundaries only) ----------------------------------
    def submit(self, req: Request):
        # parse eagerly so a bad decoding string / unsupported mode fails at
        # submit time, not mid-batch at admission
        dm = decoding.parse(req.decoding)
        if dm.kind == decoding.SPEC:
            if self.draft_params is None:
                raise ValueError(
                    "spec decoding needs a draft view: construct the engine "
                    "with spec_draft_planes=<planes> (and a packed-store "
                    "quant config)")
            if not self._spec_ok:
                raise ValueError(
                    f"self-speculative decoding unsupported for family "
                    f"{self.cfg.family!r}: its cache holds cumulative "
                    "(SSM/conv) state that cannot rewind rejected drafts")
        if dm.kind == decoding.BEAM and dm.beam_width > self.max_batch:
            raise ValueError(
                f"beam width {dm.beam_width} exceeds max_batch "
                f"{self.max_batch}: the W hypotheses are W pool slots")
        req.output = []
        self.queue.append(req)

    def _truncate(self, req: Request) -> np.ndarray:
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if prompt.size > self.max_seq:
            keep = max(1, self.max_seq - req.max_new_tokens)
            prompt = prompt[-keep:]
        return prompt

    def _set_slot(self, i: int, req: Request, prompt, caches, **extra):
        """Common admission epilogue: per-slot control state + caches.

        Decoding-mode state is reset from ``req.decoding`` every admission
        (beam MEMBER slots are stamped separately — this path admits the
        group leader, whose group id is its own slot index and whose
        cumulative score starts at 0 while members start at -inf, so the
        first expansion step fans the leader out into the full width).
        """
        st = self.state
        plen = int(prompt.size)
        live = req.max_new_tokens > 0
        dm = decoding.parse(req.decoding)
        group = i if dm.kind == decoding.BEAM else -1
        self.state = dataclasses.replace(
            st,
            pos=st.pos.at[i].set(plen - 1),
            budget=st.budget.at[i].set(req.max_new_tokens),
            last_tok=st.last_tok.at[i].set(int(prompt[-1])),
            active=st.active.at[i].set(live),
            temperature=st.temperature.at[i].set(float(req.temperature)),
            top_k=st.top_k.at[i].set(int(req.top_k)),
            top_p=st.top_p.at[i].set(float(req.top_p)),
            mode=st.mode.at[i].set(dm.kind),
            beam_group=st.beam_group.at[i].set(group),
            beam_score=st.beam_score.at[i].set(0.0),
            spec_steps=st.spec_steps.at[i].set(0),
            spec_accepted=st.spec_accepted.at[i].set(0),
            caches=caches, **extra)
        self._slot_kind[i] = dm.kind
        self._beam_hist[i] = []
        if live:
            self.slots[i] = req
        else:
            req.done = True  # nothing to generate
        return live

    def _stamp_beam_member(self, m: int, lead: int, req: Request, prompt):
        """Per-slot state for a beam MEMBER: same position/budget/params as
        the leader, score -inf so the first ``beam_select`` replaces it with
        one of the leader's top-W continuations."""
        st = self.state
        plen = int(prompt.size)
        self.state = dataclasses.replace(
            st,
            pos=st.pos.at[m].set(plen - 1),
            budget=st.budget.at[m].set(req.max_new_tokens),
            last_tok=st.last_tok.at[m].set(int(prompt[-1])),
            active=st.active.at[m].set(True),
            temperature=st.temperature.at[m].set(float(req.temperature)),
            top_k=st.top_k.at[m].set(int(req.top_k)),
            top_p=st.top_p.at[m].set(float(req.top_p)),
            mode=st.mode.at[m].set(decoding.BEAM),
            beam_group=st.beam_group.at[m].set(lead),
            beam_score=st.beam_score.at[m].set(decoding._NEG),
            spec_steps=st.spec_steps.at[m].set(0),
            spec_accepted=st.spec_accepted.at[m].set(0))
        self.slots[m] = req
        self._slot_kind[m] = decoding.BEAM
        self._beam_hist[m] = []

    def _evict_slot(self, i: int):
        """Admission rollback / group retirement: release slot ``i``'s
        reservation and deactivate it (request bookkeeping is the caller's
        problem)."""
        if self.paged:
            for bid in self._slot_blocks[i]:
                self._alloc.decref(bid)
            self._slot_blocks[i] = []
            self.state = dataclasses.replace(
                self.state,
                page_table=self.state.page_table.at[i].set(0))
        self.state = dataclasses.replace(
            self.state, active=self.state.active.at[i].set(False))
        self.slots[i] = None
        self._slot_kind[i] = decoding.NORMAL
        self._beam_hist[i] = []

    def _admit_one(self, i: int, req: Request):
        prompt = self._truncate(req)
        plen = int(prompt.size)

        # chunked prefill of prompt[:-1] into a zeroed batch-1 slot view;
        # the last token is fed to the first decode step instead
        c = self.prefill_chunk
        slot_caches = self._zero_slot
        t0 = time.perf_counter_ns()
        tc = t0
        for j in range(0, plen - 1, c):
            vl = min(c, plen - 1 - j)
            buf = np.zeros((1, c), np.int32)
            buf[0, :vl] = prompt[j:j + vl]
            slot_caches = self._prefill(
                self.params, slot_caches, jnp.asarray(buf),
                np.int32(j), np.int32(vl))
            tn = time.perf_counter_ns()
            self._h_prefill_s.observe((tn - tc) / 1e9)
            if self.tracer is not None:
                self.tracer.complete("prefill_chunk", tc, tn, cat="prefill",
                                     uid=req.uid, slot=i, offset=j, valid=vl)
            tc = tn
            self.prefill_dispatches += 1
            self.prefill_tokens += vl
        t1 = time.perf_counter_ns()
        self.prefill_s += (t1 - t0) / 1e9
        if self.tracer is not None:
            self.tracer.complete("admit", t0, t1, uid=req.uid, slot=i,
                                 prompt_len=plen, mode=req.decoding,
                                 paged=False)

        self._set_slot(i, req, prompt,
                       self._merge(self.state.caches, slot_caches,
                                   np.int32(i)))

    def _admit_one_paged(self, i: int, req: Request) -> bool:
        """Paged admission: reserve blocks, reuse shared-prefix blocks,
        prefill only the unshared suffix. Returns False (leaving the
        request queued and the engine untouched) when the pool cannot
        grant the reservation."""
        prompt = self._truncate(req)
        plen = int(prompt.size)
        bs = self.cache_block_size

        # all-or-nothing reservation covering every position this slot can
        # ever touch: prefill writes 0..plen-2, decode writes plen-1 onward,
        # and a finished slot keeps (idempotently) rewriting its frozen
        # position until the next chunk boundary
        n_need = 0
        if self.has_pooled:
            cap = min(plen + max(0, req.max_new_tokens), self.max_seq)
            n_need = max(1, -(-cap // bs))

        # shared-prefix lookup: block j is shared READ-ONLY only if it lies
        # entirely below the first decode write — (j+1)*bs <= plen-1
        shared: List[int] = []
        cow_src = None
        m_share = (plen - 1) // bs
        if self._prefix is not None:
            for j in range(min(m_share, n_need)):
                key = blockpool.chain_key(prompt[:(j + 1) * bs])
                bid = self._prefix.get(key)
                if bid is None or key in self._pending_keys:
                    break
                shared.append(bid)
            if len(shared) == m_share and (m_share + 1) * bs == plen:
                # divergence block ends exactly at plen: its content is a
                # pure function of the prompt, but decode overwrites its
                # last position — reuse is copy-on-write (pending entries
                # are fine here: the copy's tail is rewritten before read)
                cow_src = self._prefix.get(blockpool.chain_key(prompt))
        m0 = len(shared)

        # pin shared blocks BEFORE eviction can run: evict_until() may drop
        # the very entries we just looked up, and an unpinned block could be
        # freed and reissued to this same allocation
        for bid in shared:
            self._alloc.incref(bid)
        if cow_src is not None:
            self._alloc.incref(cow_src)
        n_priv = n_need - m0
        blocks = self._alloc.alloc(n_priv)
        if blocks is None and self._prefix is not None:
            self._prefix.evict_until(n_priv)
            blocks = self._alloc.alloc(n_priv)
        if blocks is None:
            for bid in shared:
                self._alloc.decref(bid)
            if cow_src is not None:
                self._alloc.decref(cow_src)
            return False  # admission blocked: not enough free blocks

        row = shared + blocks
        self._slot_blocks[i] = list(row)
        row_arr = np.zeros(self.blocks_per_slot, np.int32)
        row_arr[:len(row)] = row
        st = self.state
        new_pt = st.page_table.at[i].set(jnp.asarray(row_arr))
        caches = st.caches

        if cow_src is not None:
            caches = self._copy_block(caches, np.int32(cow_src),
                                      np.int32(blocks[0]))
            self._alloc.decref(cow_src)  # private copy taken
            start = (m0 + 1) * bs
        else:
            start = m0 * bs
        self.prefill_tokens_reused += min(start, plen - 1)
        if self.tracer is not None and (m0 > 0 or cow_src is not None):
            self.tracer.instant("prefix_hit", cat="prefill", uid=req.uid,
                                slot=i, shared_blocks=m0,
                                cow=cow_src is not None,
                                tokens_reused=min(start, plen - 1))

        # prefill the unshared suffix straight into the pool (prefix hits
        # skip whole chunks; a full COW hit skips prefill entirely)
        t0 = time.perf_counter_ns()
        tc = t0
        if start >= plen - 1 and self._all_pooled:
            # everything came from shared blocks and there is no slot-
            # resident state to reset: the fan-out fast path is pure
            # bookkeeping, zero device work
            new_caches = caches
        else:
            page_row = jnp.asarray(row_arr)[None, :]
            # fresh zero views for the unpooled leaves each admit: the
            # previous admit's views were donated (invalidated) by the
            # prefill jit
            view = jax.tree.map(
                lambda c, z, pooled: c if pooled
                else jnp.zeros(z.shape, z.dtype),
                caches, self._zero_slot, self._pooled)
            c = self.prefill_chunk
            for j in range(start, plen - 1, c):
                vl = min(c, plen - 1 - j)
                buf = np.zeros((1, c), np.int32)
                buf[0, :vl] = prompt[j:j + vl]
                view = self._prefill_paged(self.params, view,
                                           jnp.asarray(buf), np.int32(j),
                                           np.int32(vl), page_row)
                tn = time.perf_counter_ns()
                self._h_prefill_s.observe((tn - tc) / 1e9)
                if self.tracer is not None:
                    self.tracer.complete("prefill_chunk", tc, tn,
                                         cat="prefill", uid=req.uid, slot=i,
                                         offset=j, valid=vl)
                tc = tn
                self.prefill_dispatches += 1
                self.prefill_tokens += vl
            # merge eagerly in python: pooled leaves pass through BY
            # REFERENCE (the pool was updated in place via donation);
            # unpooled leaves are written into slot i of the dense half
            new_caches = jax.tree.map(
                lambda cc, v, bax, pooled: v if pooled else
                jax.lax.dynamic_update_slice_in_dim(
                    cc, v.astype(cc.dtype), i, axis=bax),
                caches, view, self._axes, self._pooled)
        t1 = time.perf_counter_ns()
        self.prefill_s += (t1 - t0) / 1e9
        if self.tracer is not None:
            self.tracer.complete("admit", t0, t1, uid=req.uid, slot=i,
                                 prompt_len=plen, mode=req.decoding,
                                 paged=True, shared_blocks=m0,
                                 cow=cow_src is not None)

        live = self._set_slot(i, req, prompt, new_caches, page_table=new_pt)

        # register freshly-written shareable blocks for future prompts
        if self._prefix is not None:
            for j in range(m0, min(m_share, n_need)):
                self._prefix.put(
                    blockpool.chain_key(prompt[:(j + 1) * bs]), row[j])
            if live and (m_share + 1) * bs == plen and m_share < len(row):
                # divergence entry: valid for COW immediately, but its last
                # position is only written by this slot's first decode
                # chunk — mark pending so no one shares it by reference yet
                key = blockpool.chain_key(prompt)
                self._prefix.put(key, row[m_share])
                self._pending_keys.add(key)
        if not live:
            # nothing to generate: the slot never occupies, so retire its
            # reservation now (prefix-registered blocks survive via the
            # cache's own ref)
            for bid in self._slot_blocks[i]:
                self._alloc.decref(bid)
            self._slot_blocks[i] = []
            self.state = dataclasses.replace(
                self.state, page_table=self.state.page_table.at[i].set(0))
        return True

    def _admit_beam(self, slots_w: List[int], req: Request) -> bool:
        """Admit a beam request into ``len(slots_w)`` slots: leader via the
        ordinary admission path (prefill once), members fork the leader —
        shared-prefix blocks by reference plus private-block content copies
        in paged mode (the PR-7 COW fan-out), full cache-row copies for
        unpooled leaves. Returns False (request left queued, engine rolled
        back) if the pool cannot grant every member's reservation."""
        lead = slots_w[0]
        if self.paged:
            if not self._admit_one_paged(lead, req):
                return False
        else:
            self._admit_one(lead, req)
        prompt = self._truncate(req)
        stamped = [lead]
        for m in slots_w[1:]:
            if self.paged:
                lead_row = self._slot_blocks[lead]
                # blocks strictly below the first decode write (plen-1) are
                # immutable for the rest of the group's life: share them by
                # reference. The divergence block and everything after is
                # per-hypothesis mutable -> private content copy.
                m_share = min((int(prompt.size) - 1) // self.cache_block_size,
                              len(lead_row))
                n_priv = len(lead_row) - m_share
                blocks = self._alloc.alloc(n_priv)
                if blocks is None and self._prefix is not None:
                    self._prefix.evict_until(n_priv)
                    blocks = self._alloc.alloc(n_priv)
                if blocks is None:
                    for s in stamped:
                        self._evict_slot(s)
                    return False
                for bid in lead_row[:m_share]:
                    self._alloc.incref(bid)
                caches = self.state.caches
                for src, dst in zip(lead_row[m_share:], blocks):
                    caches = self._copy_block(caches, np.int32(src),
                                              np.int32(dst))
                row = lead_row[:m_share] + blocks
                self._slot_blocks[m] = list(row)
                row_arr = np.zeros(self.blocks_per_slot, np.int32)
                row_arr[:len(row)] = row
                self.state = dataclasses.replace(
                    self.state,
                    page_table=self.state.page_table.at[m].set(
                        jnp.asarray(row_arr)),
                    caches=self._fork_slot(caches, np.int32(lead),
                                           np.int32(m)))
            else:
                self.state = dataclasses.replace(
                    self.state,
                    caches=self._fork_slot(self.state.caches, np.int32(lead),
                                           np.int32(m)))
            self._stamp_beam_member(m, lead, req, prompt)
            stamped.append(m)
        self._beam_groups[lead] = {
            "req": req, "slots": list(slots_w),
            "live": set(slots_w), "finished": []}
        return True

    def _admit(self) -> int:
        n = 0
        while self.queue:
            req = self.queue[0]
            dm = decoding.parse(req.decoding)
            width = (dm.beam_width
                     if dm.kind == decoding.BEAM and req.max_new_tokens > 0
                     else 1)
            free = [i for i, r in enumerate(self.slots) if r is None]
            if len(free) < width:
                break  # FIFO head-of-line: wait for slots to free
            self.admit_attempts += 1
            if dm.kind == decoding.BEAM and req.max_new_tokens > 0:
                if not self._admit_beam(free[:width], req):
                    self.admit_blocked += 1
                    break  # wait for blocks to free
            elif self.paged:
                if not self._admit_one_paged(free[0], req):
                    self.admit_blocked += 1
                    break
            else:
                self._admit_one(free[0], req)
            self.queue.popleft()
            if self.tracer is not None:
                self.tracer.async_begin("request", id=req.uid,
                                        mode=req.decoding, width=width)
                if req.done:  # max_new_tokens <= 0: retires at admission
                    self.tracer.async_end("request", id=req.uid, tokens=0)
            n += 1
        return n

    def _find_beam_group(self, i: int) -> Optional[Dict[str, Any]]:
        for g in self._beam_groups.values():
            if i in g["slots"]:
                return g
        return None

    def step(self) -> bool:
        """One chunk cycle: admit, decode N tokens/slot, retire."""
        admitted = self._admit()
        occupied = [i for i, r in enumerate(self.slots) if r is not None]
        occ = len(occupied)
        self.peak_active_slots = max(self.peak_active_slots, occ)
        if not occupied:
            if self.paged and self.queue and admitted == 0:
                # no live slot can ever free blocks: the head request's
                # reservation exceeds what the pool can ever grant
                raise RuntimeError(
                    f"request {self.queue[0].uid} needs more cache blocks "
                    f"than the pool can ever free (num_cache_blocks="
                    f"{self.num_cache_blocks}, block={self.cache_block_size})")
            return admitted > 0
        self._h_occupancy.observe(occ / self.max_batch)
        # decode-variant dispatch on the pool's current mode mix: a pure
        # NORMAL pool runs the legacy two-arg program unchanged (same AOT
        # artifact bench_serving compiles); beam/spec pools run the general
        # program with the matching static flags
        has_beam = any(self._slot_kind[i] == decoding.BEAM for i in occupied)
        has_spec = any(self._slot_kind[i] == decoding.SPEC for i in occupied)
        t0 = time.perf_counter_ns()
        if not (has_beam or has_spec):
            self.state, toks, valid = self._decode(self.params, self.state)
            toks, valid, alive = jax.device_get(
                (toks, valid, self.state.active))  # THE once-per-chunk sync
            toks, valid = toks[:, :, None], valid[:, :, None]  # [N, B, 1]
            parent = scores = sst = sacc = None
        else:
            fn = self._get_decode(has_beam, has_spec)
            dp = self.draft_params if has_spec else self.params
            self.state, toks, valid, parent = fn(self.params, dp, self.state)
            toks, valid, parent, alive, scores, sst, sacc = jax.device_get(
                (toks, valid, parent, self.state.active,
                 self.state.beam_score, self.state.spec_steps,
                 self.state.spec_accepted))  # still ONE sync per chunk
        t1 = time.perf_counter_ns()  # the timestamp the sync already earned
        self.decode_syncs += 1
        self._h_chunk_s.observe((t1 - t0) / 1e9)
        if self.tracer is not None:
            self.tracer.complete(
                "decode_chunk", t0, t1, cat="decode", steps=self.decode_chunk,
                active_slots=occ, occupancy=occ / self.max_batch,
                has_beam=has_beam, has_spec=has_spec)
        if self.paged and self._pending_keys:
            # every pending divergence entry's origin slot just ran its
            # first decode chunk, writing the entry's last position: promote
            # to fully shareable
            self._pending_keys.clear()
        for n in range(toks.shape[0]):
            if has_beam:
                # hypothesis histories fork exactly like the device caches:
                # read every parent's history BEFORE committing any
                moved = {}
                for i in occupied:
                    if self._slot_kind[i] == decoding.BEAM and valid[n, i, 0]:
                        moved[i] = list(self._beam_hist[parent[n, i]])
                for i, hist in moved.items():
                    hist.append(int(toks[n, i, 0]))
                    self._beam_hist[i] = hist
                    self.decode_tokens += 1
            for i in occupied:
                if self._slot_kind[i] == decoding.BEAM:
                    continue  # recorded above (hypotheses fork, not append)
                for j in range(valid.shape[2]):
                    if valid[n, i, j]:
                        self.slots[i].output.append(int(toks[n, i, j]))
                        self.decode_tokens += 1
        retired = []
        for i in occupied:
            if alive[i]:
                continue
            kind = self._slot_kind[i]
            if kind == decoding.BEAM:
                # freeze the finished hypothesis; the slot stays reserved
                # (not refillable) until every group member retires, so the
                # group id — the leader's slot index — stays unambiguous
                g = self._find_beam_group(i)
                if g is not None and i in g["live"]:
                    g["live"].discard(i)
                    g["finished"].append(
                        (list(self._beam_hist[i]), float(scores[i])))
                continue
            req = self.slots[i]
            if kind == decoding.SPEC:
                vs, at = int(sst[i]), int(sacc[i])
                req.spec_stats = {"verify_steps": vs,
                                  "accepted_draft_tokens": at}
                self.spec_verify_steps += vs
                self.spec_accepted_tokens += at
            req.done = True
            if self.tracer is not None:
                self.tracer.async_end("request", id=req.uid,
                                      tokens=len(req.output or []))
            self.slots[i] = None  # retire -> refillable next boundary
            retired.append(i)
        # beam groups with no live hypothesis left: rank and retire together
        for lead in list(self._beam_groups):
            g = self._beam_groups[lead]
            if g["live"]:
                continue
            req = g["req"]
            hyps = g["finished"]
            norm = decoding.rank_hypotheses(
                [s for _, s in hyps], [len(t) for t, _ in hyps],
                self.beam_length_alpha)
            order = np.argsort(-np.asarray(norm), kind="stable")
            req.beams = [(list(hyps[k][0]), float(norm[k])) for k in order]
            req.output = list(req.beams[0][0]) if req.beams else []
            req.done = True
            if self.tracer is not None:
                self.tracer.async_end("request", id=req.uid,
                                      tokens=len(req.output),
                                      hypotheses=len(req.beams))
            for m in g["slots"]:
                self.slots[m] = None
                self._slot_kind[m] = decoding.NORMAL
                self._beam_hist[m] = []
                retired.append(m)
            del self._beam_groups[lead]
        if self.paged and retired:
            for i in retired:
                for bid in self._slot_blocks[i]:
                    self._alloc.decref(bid)
                self._slot_blocks[i] = []
            # point retired rows at the null block so their frozen-position
            # writes stop touching (possibly reissued) pool blocks
            self.state = dataclasses.replace(
                self.state,
                page_table=self.state.page_table
                .at[jnp.asarray(retired)].set(0))
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while any(s is not None for s in self.slots) or self.queue:
            if not self.step():
                break
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("serving did not converge")
        return ticks

    # -- kernel autotuning --------------------------------------------------
    def pretune(self, *, repeats: int = 2, max_candidates: int = 4,
                verbose: bool = False) -> int:
        """Measure-tune every mpGEMM shape this engine dispatches.

        Decode steps run M = max_batch activations per projection; prefill
        chunks run M = prefill_chunk. Tunes each (M, packed-weight shape)
        pair missing from the tuning cache and persists the cache, so a
        subsequent trace with ``fusion="tuned"`` resolves every dispatch
        from measured data (trace-time dict hit, sub-ms). Only meaningful
        for ``mpgemm_mode="lut_pallas"`` — the other modes have no block
        knobs to tune.
        """
        from repro.core import autotune
        cache = self.tuning_cache or autotune.get_active()
        if cache is None:
            raise ValueError("pretune() needs a tuning cache — construct "
                             "the engine with tuning_cache=<path>")
        q = self.cfg.quant or {}
        if q.get("mpgemm_mode") != "lut_pallas":
            warnings.warn("pretune() is a no-op for mpgemm_mode="
                          f"{q.get('mpgemm_mode')!r} (no kernel knobs)")
            return 0
        from repro.core.mpgemm import resolve_table_quant
        n = autotune.pretune_params(
            self.params, [self.max_batch, self.prefill_chunk], cache=cache,
            table_quant=resolve_table_quant(q.get("table_quant", "per_row")),
            plan=self.plan,
            repeats=repeats, max_candidates=max_candidates, verbose=verbose)
        if cache.path is not None:
            cache.save()
        return n

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        # latency/occupancy come from the bounded-reservoir histograms;
        # percentiles interpolate between closest ranks (the old nearest-
        # rank lambda reported p50 of 3 samples as the second LARGEST)
        h = self._h_chunk_s
        toks = max(1, self.decode_tokens)
        decode_s = h.total
        out = {
            "decode_chunk": self.decode_chunk,
            "prefill_chunk": self.prefill_chunk,
            "decode_syncs": self.decode_syncs,
            "decode_tokens": self.decode_tokens,
            "host_syncs_per_token": self.decode_syncs / toks,
            "prefill_dispatches": self.prefill_dispatches,
            "p50_chunk_ms": h.percentile(0.50) * 1e3,
            "p95_chunk_ms": h.percentile(0.95) * 1e3,
            # decode-only throughput: excludes prefill/admit/compile, so it
            # is the number that isolates a decode-chunk latency cliff
            "decode_tok_s": self.decode_tokens / decode_s if decode_s else 0.0,
            # cache-pool observability (meaningful for dense too: the HBM
            # number is what the paged/dense capacity comparison fixes)
            "paged": self.paged,
            "mesh": (None if self.plan is None else dict(zip(
                self.plan.mesh.axis_names, self.plan.mesh.devices.shape))),
            "cache_hbm_bytes": int(sum(
                l.nbytes for l in jax.tree.leaves(self.state.caches))),
            "slot_occupancy": self._h_occupancy.mean,
            "peak_active_slots": self.peak_active_slots,
            "admit_attempts": self.admit_attempts,
            "admit_blocked": self.admit_blocked,
            "admission_blocked_rate": (self.admit_blocked
                                       / max(1, self.admit_attempts)),
            "prefill_s": self.prefill_s,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_reused": self.prefill_tokens_reused,
        }
        if self.paged:
            out["cache_block_size"] = self.cache_block_size
            out["num_cache_blocks"] = self.num_cache_blocks
            out["blocks_in_use"] = self._alloc.num_used
            if self._prefix is not None:
                out["prefix_cache"] = {
                    "entries": len(self._prefix),
                    "hits": self._prefix.hits,
                    "misses": self._prefix.misses,
                    "evictions": self._prefix.evictions,
                }
        if self.draft_params is not None:
            # retired totals plus the still-occupied spec slots' live
            # counters (stats() is a rare observability call, so the extra
            # sync here does not count against the decode loop's one/chunk)
            sst, sacc = jax.device_get(
                (self.state.spec_steps, self.state.spec_accepted))
            vs = self.spec_verify_steps + sum(
                int(sst[i]) for i in range(self.max_batch)
                if self.slots[i] is not None
                and self._slot_kind[i] == decoding.SPEC)
            at = self.spec_accepted_tokens + sum(
                int(sacc[i]) for i in range(self.max_batch)
                if self.slots[i] is not None
                and self._slot_kind[i] == decoding.SPEC)
            out["spec"] = {
                "spec_k": self.spec_k,
                "draft_planes": int(self.spec_draft_planes),
                "draft_extra_hbm_bytes": int(self.draft_extra_hbm_bytes),
                "verify_steps": vs,
                "accepted_draft_tokens": at,
                # +1 for the verify forward's own token (replacement or
                # bonus): tokens emitted per verify round
                "mean_emitted_per_step": ((at + vs) / max(1, vs)),
                "mean_accepted_per_step": at / max(1, vs),
            }
        if self._beam_groups or any(
                k == decoding.BEAM for k in self._slot_kind):
            out["beam"] = {
                "active_groups": len(self._beam_groups),
                "length_alpha": self.beam_length_alpha,
            }
        if self.tuning_cache is not None:
            out["tuning_cache"] = self.tuning_cache.counters()
        rec = dispatch_obs.get_active()
        if rec is not None:
            s = rec.summary()
            out["dispatch"] = {k: s[k] for k in
                               ("decisions", "tuned", "heuristic", "forced")}
        return out

    def metrics_snapshot(self) -> dict:
        """JSON-able registry snapshot with ``stats()`` mirrored in as
        ``engine_*`` gauges (counters/gauges/histogram summaries)."""
        export_stats(self.metrics, self.stats(), prefix="engine")
        return self.metrics.snapshot()

    def prometheus_text(self) -> str:
        """Prometheus text exposition of the same snapshot."""
        export_stats(self.metrics, self.stats(), prefix="engine")
        return self.metrics.prometheus_text()
