"""Device-resident continuous-batching decode engine.

The engine owns a fixed pool of ``max_batch`` decode slots backed by one
static-shape KV/SSM cache. Weights are the packed low-bit serving format
(``serve_quantized`` params): batched decode is exactly the mpGEMM regime
the paper targets — memory-bound GEMV-shaped ops where the 4–16x
weight-traffic cut pays off — so the engine loop must not squander the
kernel's win on host round-trips.

All per-token control state lives ON DEVICE in an :class:`EngineState`
pytree (per-slot ``pos``/``budget``/``last_tok``/``active``, per-slot
sampling params, the PRNG key, and the caches). Three jitted programs:

  * ``decode_chunk``: ``jax.lax.scan`` over N decode steps for the whole
    pool — per-slot active masking, on-device budget/max-seq/EOS stopping,
    on-device per-slot sampling — emitting a ``[N, B]`` token buffer. The
    host syncs ONCE per chunk (read tokens + liveness), not once per token.
  * ``prefill_chunk``: ONE fixed-``[1, C]``-shape program that writes a
    prompt chunk into a batch-1 slot-cache view at a dynamic cache offset
    (no per-length recompiles, no B× wasted full-batch forward per admit).
    The LM head of a prefill chunk is dead code (only caches are returned),
    so XLA drops the vocab projection entirely.
  * ``merge``: write the batch-1 slot caches back into the pool at the
    slot's batch index (per-leaf batch axes via ``kvcache.batch_axes``).

Admission leaves the LAST prompt token out of prefill: it becomes the
slot's ``last_tok`` at ``pos = len(prompt) - 1``, so the first generated
token falls out of the decode scan itself — admission costs zero host syncs
and zero sampling dispatches.

Admit/retire stay on host but only run at chunk boundaries, preserving
continuous-batching semantics: finished slots are refilled from the queue
without touching in-flight ones. Per-slot positions mean one program serves
ragged sequence lengths (attention masks by each slot's own valid length;
SSM state is position-free).

Known edges (documented, covered by tests):
  * a prompt longer than ``max_seq`` is truncated to its last
    ``max(1, max_seq - max_new_tokens)`` tokens (room to generate);
  * a prompt that already fills the cache (``len == max_seq``) yields no
    tokens (there is no cache position left to write the first one);
  * ``max_new_tokens <= 0`` completes immediately with no output;
  * slots that finish mid-chunk idle until the next chunk boundary (their
    compute is masked out, their state is reset at the next admit).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchConfig
from repro.distributed import sharding as shrules
from repro.distributed.sharding import AxisPlan, plan_scope
from repro.models import api, kvcache
from repro.serving import blockpool
from repro.serving.sampler import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 32
    temperature: float = 0.0           # <= 0 -> greedy
    top_k: int = 0                     # 0 -> disabled
    top_p: float = 1.0                 # >= 1 -> disabled
    done: bool = False
    output: Optional[List[int]] = None


@dataclasses.dataclass
class EngineState:
    """Device-resident engine state (registered pytree; one leaf per field).

    All leaves are arrays: ``[B]`` per-slot control/sampling vectors, the
    PRNG key, and the full cache pytree. The decode scan threads the whole
    state through ``jax.lax.scan``; the host only reads it back at chunk
    boundaries.
    """
    pos: jax.Array          # [B] i32  next cache write position (= valid len)
    budget: jax.Array       # [B] i32  remaining new tokens
    last_tok: jax.Array     # [B] i32  next token to feed
    active: jax.Array       # [B] bool decoding live
    temperature: jax.Array  # [B] f32  per-slot sampling params
    top_k: jax.Array        # [B] i32
    top_p: jax.Array        # [B] f32
    key: jax.Array          # PRNG key
    page_table: jax.Array   # [B, blocks_per_slot] i32 pool block per logical
                            # page (paged mode; [B, 1] zeros when dense)
    caches: Any             # model cache pytree


jax.tree_util.register_dataclass(
    EngineState,
    data_fields=["pos", "budget", "last_tok", "active", "temperature",
                 "top_k", "top_p", "key", "page_table", "caches"],
    meta_fields=[])


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 512, seed: int = 0, decode_chunk: int = 8,
                 prefill_chunk: int = 32, eos_id: Optional[int] = None,
                 tuning_cache: Optional[str] = None,
                 cache_block_size: Optional[int] = None,
                 num_cache_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 kv_cache_dtype: Optional[str] = None,
                 plan: Optional[AxisPlan] = None):
        self.cfg = cfg
        # Tensor/data-parallel serving: ``plan`` shards the packed weights
        # (named_sharding_tree), the engine state and the cache pool across
        # the plan's mesh, and every jitted program traces inside
        # ``plan_scope`` so the models' logical-axis shard() hooks fire.
        # ``plan=None`` is the single-device default — identical to a 1x1
        # mesh plan, where every constraint resolves to replication.
        self.plan = plan
        if plan is not None:
            params = jax.device_put(
                params, shrules.named_sharding_tree(params, plan))
        elif (cfg.quant and jax.default_backend() == "cpu"
              and cfg.quant.get("mpgemm_mode", "lut_xla") == "lut_xla"
              and cfg.quant.get("store") is None):
            # Single-device CPU serving: the XLA LUT path has no hardware
            # lookup unit, so a packed store forces a packed->CW expansion
            # inside every decode step. Hoist it: convert once to the
            # offline-CW store (bit-exact, same lut_xla epilogue). Pin
            # quant["store"]="packed" to keep packed planes resident.
            from repro.models.quantized import to_cw_params
            params = to_cw_params(params)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.decode_chunk = max(1, decode_chunk)
        self.prefill_chunk = max(1, min(prefill_chunk, max_seq))
        self.eos_id = eos_id
        self._seed = seed
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch

        # persistent kernel-tuning cache: activates fusion="tuned" lookups
        # for every mpGEMM dispatched by this engine's jitted programs
        # (trace-time dict hits; populate via pretune() or bench_autotune)
        self.tuning_cache = None
        if tuning_cache is not None:
            from repro.core import autotune
            self.tuning_cache = autotune.configure(tuning_cache)

        kv_dt = kv_cache_dtype or cfg.kv_cache_dtype
        self._cache_dtype = "int8" if kv_dt == "int8" else jnp.float32

        # per-leaf batch axes of the cache pytree (shape-diff discovery:
        # hybrid stacks carry batch at axis 2, plain stacks at axis 1)
        c1 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, max_seq, dtype=self._cache_dtype))
        c2 = jax.eval_shape(
            lambda: api.init_cache(cfg, 2, max_seq, dtype=self._cache_dtype))
        self._axes = kvcache.batch_axes(c1, c2)
        # per-leaf sequence axes (same probe trick, varying s_cache): leaves
        # with no sequence axis — SSM conv/ssm state, image/cross KV — are
        # O(1) per slot and stay dense slot-indexed even in paged mode
        s1 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, 16, dtype=self._cache_dtype))
        s2 = jax.eval_shape(
            lambda: api.init_cache(cfg, 1, 32, dtype=self._cache_dtype))
        self._seq_axes = kvcache.seq_axes(s1, s2)
        # zero batch-1 slot caches: the prefill starting point for every
        # admit (a retiring request's state must never leak into its slot's
        # next occupant — SSM states are cumulative)
        self._zero_slot = api.init_cache(cfg, 1, max_seq,
                                         dtype=self._cache_dtype)

        # ---- block-paged cache pool (optional) ----------------------------
        self.paged = cache_block_size is not None
        self.prefix_caching = bool(prefix_cache) and self.paged
        self._alloc: Optional[blockpool.BlockAllocator] = None
        self._prefix: Optional[blockpool.PrefixCache] = None
        if self.paged:
            bs = int(cache_block_size)
            if bs < 1 or max_seq % bs != 0:
                raise ValueError(
                    f"cache_block_size={bs} must be >= 1 and divide "
                    f"max_seq={max_seq}: the gathered paged view must be "
                    f"exactly max_seq long for bit-exact parity with dense")
            self.cache_block_size = bs
            self.blocks_per_slot = max_seq // bs
            if num_cache_blocks is None:
                # dense-equivalent capacity: every slot can hold max_seq,
                # plus the reserved null block
                num_cache_blocks = max_batch * self.blocks_per_slot + 1
            if num_cache_blocks < self.blocks_per_slot + 1:
                raise ValueError(
                    f"num_cache_blocks={num_cache_blocks} cannot hold even "
                    f"one max_seq={max_seq} request at block size {bs} "
                    f"(need >= {self.blocks_per_slot + 1} incl. null block)")
            self.num_cache_blocks = int(num_cache_blocks)

            # pooled leaves must carry (batch, seq) adjacently so that
            # init_cache(cfg, num_blocks, block_size) IS the pool ctor
            def _check(path, bax, sax):
                if sax >= 0 and sax != bax + 1:
                    raise ValueError(
                        f"cannot page cache leaf at "
                        f"{jax.tree_util.keystr(path)!r}: sequence axis "
                        f"{sax} is not adjacent to batch axis {bax}")
                return sax >= 0
            self._pooled = jax.tree_util.tree_map_with_path(
                _check, self._axes, self._seq_axes)
            pooled_leaves = jax.tree.leaves(self._pooled)
            self.has_pooled = any(pooled_leaves)
            self._all_pooled = all(pooled_leaves)
            if self.prefix_caching and not all(jax.tree.leaves(self._pooled)):
                warnings.warn(
                    "prefix caching needs every cache leaf paged; family="
                    f"{cfg.family!r} holds slot-resident state (SSM/cross "
                    "KV) that cannot fan out by block reference — disabled")
                self.prefix_caching = False

            nb_total = self.num_cache_blocks

            def _build_paged():
                # one jitted builder selecting pool vs dense per leaf: XLA
                # DCEs the unused half, so SSM state is never allocated at
                # batch=num_blocks nor attention KV at [B, max_seq] density
                pool = api.init_cache(cfg, nb_total, bs,
                                      dtype=self._cache_dtype)
                dense = api.init_cache(cfg, max_batch, max_seq,
                                       dtype=self._cache_dtype)
                return jax.tree.map(
                    lambda p, d, pooled: p if pooled else d,
                    pool, dense, self._pooled)

            self._build_paged = jax.jit(_build_paged)
            # prefill view: pool leaves ride through whole; unpooled leaves
            # are a batch-1 slot view (donated through the chunk loop)
            self._prefill_paged = jax.jit(self._paged_prefill_impl,
                                          donate_argnums=(1,))
            self._copy_block = jax.jit(self._copy_block_impl,
                                       donate_argnums=(0,))

        # the decode carry (caches dominate it) is donated: without donation
        # every chunk dispatch copies the full [B, S] cache pytree just to
        # write the new state next to it — pure memory traffic that grows
        # with max_batch·max_seq and was a visible slice of per-chunk
        # latency at large decode_chunk settings
        self._decode = jax.jit(self._decode_chunk_impl, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_chunk_impl)
        self._merge = jax.jit(
            lambda caches, slot, i: kvcache.merge_batch(
                caches, slot, self._axes, i))

        self.reset(seed=seed)

    # -- lifecycle ----------------------------------------------------------
    def reset(self, seed: Optional[int] = None):
        """Clear queue/slots/state/counters; keep compiled programs."""
        if seed is None:
            seed = self._seed
        b = self.max_batch
        self.queue = deque()
        self.slots = [None] * b
        if self.paged:
            self._alloc = blockpool.BlockAllocator(self.num_cache_blocks)
            self._prefix = (blockpool.PrefixCache(self._alloc)
                            if self.prefix_caching else None)
            self._pending_keys: set = set()  # divergence entries whose last
            # position is unwritten until the origin's first decode chunk
            self._slot_blocks: List[List[int]] = [[] for _ in range(b)]
            caches = self._build_paged()
            page_table = jnp.zeros((b, self.blocks_per_slot), jnp.int32)
        else:
            caches = api.init_cache(self.cfg, b, self.max_seq,
                                    dtype=self._cache_dtype)
            page_table = jnp.zeros((b, 1), jnp.int32)
        self.state = EngineState(
            pos=jnp.zeros(b, jnp.int32),
            budget=jnp.zeros(b, jnp.int32),
            last_tok=jnp.zeros(b, jnp.int32),
            active=jnp.zeros(b, bool),
            temperature=jnp.zeros(b, jnp.float32),
            top_k=jnp.zeros(b, jnp.int32),
            top_p=jnp.ones(b, jnp.float32),
            key=jax.random.key(seed),
            page_table=page_table,
            caches=caches)
        if self.plan is not None:
            self.state = jax.device_put(
                self.state, self._engine_state_shardings(self.state))
        self.decode_syncs = 0       # host round-trips in the decode loop
        self.decode_tokens = 0      # tokens emitted by decode chunks
        self.prefill_dispatches = 0
        self.chunk_latencies: List[float] = []  # seconds per decode chunk
        self.prefill_s = 0.0        # wall seconds spent in prefill dispatch
        self.prefill_tokens = 0     # prompt tokens actually prefilled
        self.prefill_tokens_reused = 0  # prompt tokens served from shared
        # blocks (prefix cache hits) instead of being re-prefilled
        self.admit_attempts = 0
        self.admit_blocked = 0      # admissions deferred for lack of blocks
        self.occupancy_samples: List[float] = []  # slot occupancy per chunk
        self.peak_active_slots = 0

    def _engine_state_shardings(self, state: EngineState) -> EngineState:
        """NamedSharding pytree for the engine state under ``self.plan``.

        Per-slot control vectors and the DENSE cache batch dim shard over
        the plan's batch axes; attention KV heads (dim seq+1) and SSM
        feature dims (dim batch+1) shard over the model axis, matching the
        column-parallel projections that produce them. Paged POOL leaves
        keep their block dim replicated: page tables index the global pool,
        so any slot may reference any block — sharding blocks over data
        would turn every page gather into a cross-shard collective. All of
        this is layout-only (GSPMD), so every fallback is replication, not
        an error."""
        plan = self.plan
        mesh = plan.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        batch_ax = plan.resolve("batch")
        model_ax = plan.resolve("model")

        def ns(shape, phys):
            return NamedSharding(mesh, P(*shrules.resolve_physical_spec(
                shape, phys, sizes)))

        def vec(x):
            return ns(x.shape, (batch_ax,) + (None,) * (x.ndim - 1))

        pooled = (self._pooled if self.paged
                  else jax.tree.map(lambda _: False, self._axes))

        def cache_leaf(c, bax, sax, is_pooled):
            phys = [None] * c.ndim
            if not is_pooled:
                phys[bax] = batch_ax
            feat = (sax + 1) if sax >= 0 else (bax + 1)
            if feat < c.ndim and phys[feat] is None:
                phys[feat] = model_ax
            return ns(c.shape, tuple(phys))

        caches_sh = jax.tree.map(cache_leaf, state.caches, self._axes,
                                 self._seq_axes, pooled)
        rep = NamedSharding(mesh, P())
        return EngineState(
            pos=vec(state.pos), budget=vec(state.budget),
            last_tok=vec(state.last_tok), active=vec(state.active),
            temperature=vec(state.temperature), top_k=vec(state.top_k),
            top_p=vec(state.top_p), key=rep,
            page_table=vec(state.page_table), caches=caches_sh)

    # -- jitted programs ----------------------------------------------------
    def _prefill_chunk_impl(self, params, slot_caches, tokens, offset, valid):
        """Write one [1, C] prompt chunk into a batch-1 slot-cache view at
        cache offset ``offset``; ``valid`` <= C real tokens (right-pad)."""
        with plan_scope(self.plan):
            _, new_caches, _ = api.forward(
                params, {"tokens": tokens}, self.cfg, caches=slot_caches,
                cache_pos=offset, token_valid=jnp.reshape(valid, (1,)))
        return new_caches

    def _paged_prefill_impl(self, params, view_caches, tokens, offset, valid,
                            page_row):
        """One [1, C] prompt chunk written straight into the pool: pooled
        leaves scatter through the slot's page-table row ``page_row``
        ([1, blocks_per_slot]); unpooled (SSM/cross) leaves ride along as a
        batch-1 slot view. The whole view is donated through the chunk loop,
        so pool pages are updated in place across chunks."""
        with plan_scope(self.plan):
            _, new_caches, _ = api.forward(
                params, {"tokens": tokens}, self.cfg, caches=view_caches,
                cache_pos=offset, token_valid=jnp.reshape(valid, (1,)),
                page_table=page_row)
        return new_caches

    def _copy_block_impl(self, caches, src, dst):
        """Copy-on-write: clone pool block ``src`` into ``dst`` on every
        pooled leaf (unpooled leaves pass through untouched)."""
        def one(c, bax, sax):
            if sax < 0:
                return c
            blk = jax.lax.dynamic_index_in_dim(c, src, axis=bax,
                                               keepdims=True)
            return jax.lax.dynamic_update_slice_in_dim(c, blk, dst, axis=bax)
        return jax.tree.map(one, caches, self._axes, self._seq_axes)

    def _decode_chunk_impl(self, params, state):
        """N decode steps for the whole pool in one dispatch."""
        # the page table is closed over per chunk, not threaded through the
        # scan carry: no decode step ever remaps pages
        paged_kw = ({"page_table": state.page_table} if self.paged else {})

        def step(st, _):
            key, sub = jax.random.split(st.key)
            logits, new_caches, _ = api.forward(
                params, {"tokens": st.last_tok[:, None]}, self.cfg,
                caches=st.caches, cache_pos=st.pos, **paged_kw)
            nxt = sample(sub, logits[:, -1], temperature=st.temperature,
                         top_k=st.top_k, top_p=st.top_p)
            # emit iff live and the cache has room for this token
            can = st.active & (st.pos + 1 < self.max_seq)
            hit_cap = st.active & ~can
            budget = jnp.where(can, st.budget - 1,
                               jnp.where(hit_cap, 0, st.budget))
            active = can & (budget > 0)
            if self.eos_id is not None:
                active &= nxt != self.eos_id
            st = dataclasses.replace(
                st,
                pos=st.pos + can.astype(jnp.int32),
                budget=budget,
                last_tok=jnp.where(can, nxt, st.last_tok),
                active=active,
                key=key,
                caches=new_caches)
            return st, (nxt, can)

        with plan_scope(self.plan):
            state, (toks, valid) = jax.lax.scan(
                step, state, None, length=self.decode_chunk)
        return state, toks, valid  # toks/valid: [N, B]

    # -- host loop (chunk boundaries only) ----------------------------------
    def submit(self, req: Request):
        req.output = []
        self.queue.append(req)

    def _truncate(self, req: Request) -> np.ndarray:
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if prompt.size > self.max_seq:
            keep = max(1, self.max_seq - req.max_new_tokens)
            prompt = prompt[-keep:]
        return prompt

    def _set_slot(self, i: int, req: Request, prompt, caches, **extra):
        """Common admission epilogue: per-slot control state + caches."""
        st = self.state
        plen = int(prompt.size)
        live = req.max_new_tokens > 0
        self.state = dataclasses.replace(
            st,
            pos=st.pos.at[i].set(plen - 1),
            budget=st.budget.at[i].set(req.max_new_tokens),
            last_tok=st.last_tok.at[i].set(int(prompt[-1])),
            active=st.active.at[i].set(live),
            temperature=st.temperature.at[i].set(float(req.temperature)),
            top_k=st.top_k.at[i].set(int(req.top_k)),
            top_p=st.top_p.at[i].set(float(req.top_p)),
            caches=caches, **extra)
        if live:
            self.slots[i] = req
        else:
            req.done = True  # nothing to generate
        return live

    def _admit_one(self, i: int, req: Request):
        prompt = self._truncate(req)
        plen = int(prompt.size)

        # chunked prefill of prompt[:-1] into a zeroed batch-1 slot view;
        # the last token is fed to the first decode step instead
        t0 = time.perf_counter()
        c = self.prefill_chunk
        slot_caches = self._zero_slot
        for j in range(0, plen - 1, c):
            vl = min(c, plen - 1 - j)
            buf = np.zeros((1, c), np.int32)
            buf[0, :vl] = prompt[j:j + vl]
            slot_caches = self._prefill(
                self.params, slot_caches, jnp.asarray(buf),
                np.int32(j), np.int32(vl))
            self.prefill_dispatches += 1
            self.prefill_tokens += vl
        self.prefill_s += time.perf_counter() - t0

        self._set_slot(i, req, prompt,
                       self._merge(self.state.caches, slot_caches,
                                   np.int32(i)))

    def _admit_one_paged(self, i: int, req: Request) -> bool:
        """Paged admission: reserve blocks, reuse shared-prefix blocks,
        prefill only the unshared suffix. Returns False (leaving the
        request queued and the engine untouched) when the pool cannot
        grant the reservation."""
        prompt = self._truncate(req)
        plen = int(prompt.size)
        bs = self.cache_block_size

        # all-or-nothing reservation covering every position this slot can
        # ever touch: prefill writes 0..plen-2, decode writes plen-1 onward,
        # and a finished slot keeps (idempotently) rewriting its frozen
        # position until the next chunk boundary
        n_need = 0
        if self.has_pooled:
            cap = min(plen + max(0, req.max_new_tokens), self.max_seq)
            n_need = max(1, -(-cap // bs))

        # shared-prefix lookup: block j is shared READ-ONLY only if it lies
        # entirely below the first decode write — (j+1)*bs <= plen-1
        shared: List[int] = []
        cow_src = None
        m_share = (plen - 1) // bs
        if self._prefix is not None:
            for j in range(min(m_share, n_need)):
                key = blockpool.chain_key(prompt[:(j + 1) * bs])
                bid = self._prefix.get(key)
                if bid is None or key in self._pending_keys:
                    break
                shared.append(bid)
            if len(shared) == m_share and (m_share + 1) * bs == plen:
                # divergence block ends exactly at plen: its content is a
                # pure function of the prompt, but decode overwrites its
                # last position — reuse is copy-on-write (pending entries
                # are fine here: the copy's tail is rewritten before read)
                cow_src = self._prefix.get(blockpool.chain_key(prompt))
        m0 = len(shared)

        # pin shared blocks BEFORE eviction can run: evict_until() may drop
        # the very entries we just looked up, and an unpinned block could be
        # freed and reissued to this same allocation
        for bid in shared:
            self._alloc.incref(bid)
        if cow_src is not None:
            self._alloc.incref(cow_src)
        n_priv = n_need - m0
        blocks = self._alloc.alloc(n_priv)
        if blocks is None and self._prefix is not None:
            self._prefix.evict_until(n_priv)
            blocks = self._alloc.alloc(n_priv)
        if blocks is None:
            for bid in shared:
                self._alloc.decref(bid)
            if cow_src is not None:
                self._alloc.decref(cow_src)
            return False  # admission blocked: not enough free blocks

        row = shared + blocks
        self._slot_blocks[i] = list(row)
        row_arr = np.zeros(self.blocks_per_slot, np.int32)
        row_arr[:len(row)] = row
        st = self.state
        new_pt = st.page_table.at[i].set(jnp.asarray(row_arr))
        caches = st.caches

        if cow_src is not None:
            caches = self._copy_block(caches, np.int32(cow_src),
                                      np.int32(blocks[0]))
            self._alloc.decref(cow_src)  # private copy taken
            start = (m0 + 1) * bs
        else:
            start = m0 * bs
        self.prefill_tokens_reused += min(start, plen - 1)

        # prefill the unshared suffix straight into the pool (prefix hits
        # skip whole chunks; a full COW hit skips prefill entirely)
        t0 = time.perf_counter()
        if start >= plen - 1 and self._all_pooled:
            # everything came from shared blocks and there is no slot-
            # resident state to reset: the fan-out fast path is pure
            # bookkeeping, zero device work
            new_caches = caches
        else:
            page_row = jnp.asarray(row_arr)[None, :]
            # fresh zero views for the unpooled leaves each admit: the
            # previous admit's views were donated (invalidated) by the
            # prefill jit
            view = jax.tree.map(
                lambda c, z, pooled: c if pooled
                else jnp.zeros(z.shape, z.dtype),
                caches, self._zero_slot, self._pooled)
            c = self.prefill_chunk
            for j in range(start, plen - 1, c):
                vl = min(c, plen - 1 - j)
                buf = np.zeros((1, c), np.int32)
                buf[0, :vl] = prompt[j:j + vl]
                view = self._prefill_paged(self.params, view,
                                           jnp.asarray(buf), np.int32(j),
                                           np.int32(vl), page_row)
                self.prefill_dispatches += 1
                self.prefill_tokens += vl
            # merge eagerly in python: pooled leaves pass through BY
            # REFERENCE (the pool was updated in place via donation);
            # unpooled leaves are written into slot i of the dense half
            new_caches = jax.tree.map(
                lambda cc, v, bax, pooled: v if pooled else
                jax.lax.dynamic_update_slice_in_dim(
                    cc, v.astype(cc.dtype), i, axis=bax),
                caches, view, self._axes, self._pooled)
        self.prefill_s += time.perf_counter() - t0

        live = self._set_slot(i, req, prompt, new_caches, page_table=new_pt)

        # register freshly-written shareable blocks for future prompts
        if self._prefix is not None:
            for j in range(m0, min(m_share, n_need)):
                self._prefix.put(
                    blockpool.chain_key(prompt[:(j + 1) * bs]), row[j])
            if live and (m_share + 1) * bs == plen and m_share < len(row):
                # divergence entry: valid for COW immediately, but its last
                # position is only written by this slot's first decode
                # chunk — mark pending so no one shares it by reference yet
                key = blockpool.chain_key(prompt)
                self._prefix.put(key, row[m_share])
                self._pending_keys.add(key)
        if not live:
            # nothing to generate: the slot never occupies, so retire its
            # reservation now (prefix-registered blocks survive via the
            # cache's own ref)
            for bid in self._slot_blocks[i]:
                self._alloc.decref(bid)
            self._slot_blocks[i] = []
            self.state = dataclasses.replace(
                self.state, page_table=self.state.page_table.at[i].set(0))
        return True

    def _admit(self) -> int:
        n = 0
        for i in range(self.max_batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            self.admit_attempts += 1
            if self.paged:
                if not self._admit_one_paged(i, req):
                    self.admit_blocked += 1
                    break  # FIFO head-of-line: wait for blocks to free
                self.queue.popleft()
            else:
                self.queue.popleft()
                self._admit_one(i, req)
            n += 1
        return n

    def step(self) -> bool:
        """One chunk cycle: admit, decode N tokens/slot, retire."""
        admitted = self._admit()
        occupied = [i for i, r in enumerate(self.slots) if r is not None]
        occ = len(occupied)
        self.peak_active_slots = max(self.peak_active_slots, occ)
        if not occupied:
            if self.paged and self.queue and admitted == 0:
                # no live slot can ever free blocks: the head request's
                # reservation exceeds what the pool can ever grant
                raise RuntimeError(
                    f"request {self.queue[0].uid} needs more cache blocks "
                    f"than the pool can ever free (num_cache_blocks="
                    f"{self.num_cache_blocks}, block={self.cache_block_size})")
            return admitted > 0
        self.occupancy_samples.append(occ / self.max_batch)
        t0 = time.perf_counter()
        self.state, toks, valid = self._decode(self.params, self.state)
        toks, valid, alive = jax.device_get(
            (toks, valid, self.state.active))  # THE once-per-chunk sync
        self.decode_syncs += 1
        self.chunk_latencies.append(time.perf_counter() - t0)
        if self.paged and self._pending_keys:
            # every pending divergence entry's origin slot just ran its
            # first decode chunk, writing the entry's last position: promote
            # to fully shareable
            self._pending_keys.clear()
        for n in range(toks.shape[0]):
            for i in occupied:
                if valid[n, i]:
                    self.slots[i].output.append(int(toks[n, i]))
                    self.decode_tokens += 1
        retired = []
        for i in occupied:
            if not alive[i]:
                self.slots[i].done = True
                self.slots[i] = None  # retire -> refillable next boundary
                retired.append(i)
        if self.paged and retired:
            for i in retired:
                for bid in self._slot_blocks[i]:
                    self._alloc.decref(bid)
                self._slot_blocks[i] = []
            # point retired rows at the null block so their frozen-position
            # writes stop touching (possibly reissued) pool blocks
            self.state = dataclasses.replace(
                self.state,
                page_table=self.state.page_table
                .at[jnp.asarray(retired)].set(0))
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while any(s is not None for s in self.slots) or self.queue:
            if not self.step():
                break
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("serving did not converge")
        return ticks

    # -- kernel autotuning --------------------------------------------------
    def pretune(self, *, repeats: int = 2, max_candidates: int = 4,
                verbose: bool = False) -> int:
        """Measure-tune every mpGEMM shape this engine dispatches.

        Decode steps run M = max_batch activations per projection; prefill
        chunks run M = prefill_chunk. Tunes each (M, packed-weight shape)
        pair missing from the tuning cache and persists the cache, so a
        subsequent trace with ``fusion="tuned"`` resolves every dispatch
        from measured data (trace-time dict hit, sub-ms). Only meaningful
        for ``mpgemm_mode="lut_pallas"`` — the other modes have no block
        knobs to tune.
        """
        from repro.core import autotune
        cache = self.tuning_cache or autotune.get_active()
        if cache is None:
            raise ValueError("pretune() needs a tuning cache — construct "
                             "the engine with tuning_cache=<path>")
        q = self.cfg.quant or {}
        if q.get("mpgemm_mode") != "lut_pallas":
            warnings.warn("pretune() is a no-op for mpgemm_mode="
                          f"{q.get('mpgemm_mode')!r} (no kernel knobs)")
            return 0
        from repro.core.mpgemm import resolve_table_quant
        n = autotune.pretune_params(
            self.params, [self.max_batch, self.prefill_chunk], cache=cache,
            table_quant=resolve_table_quant(q.get("table_quant", "per_row")),
            plan=self.plan,
            repeats=repeats, max_candidates=max_candidates, verbose=verbose)
        if cache.path is not None:
            cache.save()
        return n

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        lat = sorted(self.chunk_latencies)
        pct = (lambda p: lat[min(len(lat) - 1, int(p * len(lat)))]
               if lat else 0.0)
        toks = max(1, self.decode_tokens)
        decode_s = sum(self.chunk_latencies)
        occ = self.occupancy_samples
        out = {
            "decode_chunk": self.decode_chunk,
            "prefill_chunk": self.prefill_chunk,
            "decode_syncs": self.decode_syncs,
            "decode_tokens": self.decode_tokens,
            "host_syncs_per_token": self.decode_syncs / toks,
            "prefill_dispatches": self.prefill_dispatches,
            "p50_chunk_ms": pct(0.50) * 1e3,
            "p95_chunk_ms": pct(0.95) * 1e3,
            # decode-only throughput: excludes prefill/admit/compile, so it
            # is the number that isolates a decode-chunk latency cliff
            "decode_tok_s": self.decode_tokens / decode_s if decode_s else 0.0,
            # cache-pool observability (meaningful for dense too: the HBM
            # number is what the paged/dense capacity comparison fixes)
            "paged": self.paged,
            "mesh": (None if self.plan is None else dict(zip(
                self.plan.mesh.axis_names, self.plan.mesh.devices.shape))),
            "cache_hbm_bytes": int(sum(
                l.nbytes for l in jax.tree.leaves(self.state.caches))),
            "slot_occupancy": (sum(occ) / len(occ)) if occ else 0.0,
            "peak_active_slots": self.peak_active_slots,
            "admit_attempts": self.admit_attempts,
            "admit_blocked": self.admit_blocked,
            "admission_blocked_rate": (self.admit_blocked
                                       / max(1, self.admit_attempts)),
            "prefill_s": self.prefill_s,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_reused": self.prefill_tokens_reused,
        }
        if self.paged:
            out["cache_block_size"] = self.cache_block_size
            out["num_cache_blocks"] = self.num_cache_blocks
            out["blocks_in_use"] = self._alloc.num_used
            if self._prefix is not None:
                out["prefix_cache"] = {
                    "entries": len(self._prefix),
                    "hits": self._prefix.hits,
                    "misses": self._prefix.misses,
                    "evictions": self._prefix.evictions,
                }
        return out
