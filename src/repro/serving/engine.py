"""Batched serving engine with continuous batching.

The engine owns a fixed pool of ``max_batch`` decode slots backed by one
static-shape KV cache (per-slot positions; finished slots are refilled from
the request queue without touching in-flight ones — continuous batching).
Weights are the packed low-bit serving format (``serve_quantized`` params):
decode is exactly the mpGEMM regime the paper targets (memory-bound GEMV-ish
ops where the 4-16x weight-traffic cut pays off).

Two jitted programs:
  * ``prefill(params, tokens, caches) -> (next_token, caches)``  per request
    (left-padded to the slot's prompt bucket),
  * ``decode(params, tokens, caches, pos) -> (next_token, caches)`` for the
    whole pool, one token per slot per call.

Per-slot positions: attention masks by each slot's own valid length, so one
program serves ragged sequence lengths.
"""

from __future__ import annotations

import dataclasses
import queue
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig
from repro.models import api
from repro.serving.sampler import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: bool = False
    output: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_seq: int = 512, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.key = jax.random.key(seed)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)        # next write position
        self.budget = np.zeros(max_batch, np.int32)     # remaining new tokens
        self.last_tok = np.zeros(max_batch, np.int32)
        self.caches = api.init_cache(cfg, max_batch, max_seq,
                                     dtype=jnp.float32)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("plen",))

    # -- jitted programs ------------------------------------------------------
    def _prefill_impl(self, params, caches, tokens, slot, plen):
        """Prefill one slot with a prompt of (bucketed) length plen."""
        b = self.max_batch
        full = jnp.zeros((b, plen), jnp.int32).at[slot].set(tokens)
        logits, new_caches, _ = api.forward(params, {"tokens": full}, self.cfg,
                                            caches=caches, cache_pos=0)
        # merge: only this slot's cache rows advance
        def merge(old, new):
            if old.ndim < 2 or old.shape[1] != b:
                return new
            sel = (jnp.arange(b) == slot)
            bshape = (1, b) + (1,) * (old.ndim - 2)
            return jnp.where(sel.reshape(bshape), new.astype(old.dtype), old)
        merged = jax.tree.map(merge, caches, new_caches)
        return logits[slot, -1], merged

    def _decode_impl(self, params, caches, tokens, pos, key):
        """One decode tick for the whole pool. tokens [B,1], pos [B] per-slot
        positions (ragged continuous batching; attention masks per slot)."""
        logits, new_caches, _ = api.forward(
            params, {"tokens": tokens}, self.cfg, caches=caches,
            cache_pos=pos)
        nxt = sample(key, logits[:, -1], temperature=0.0)
        return nxt, new_caches

    # -- engine loop ------------------------------------------------------
    def submit(self, req: Request):
        req.output = []
        self.queue.put(req)

    def _admit(self):
        for i in range(self.max_batch):
            if self.slots[i] is None and not self.queue.empty():
                req = self.queue.get()
                plen = 1 << max(3, (len(req.prompt) - 1).bit_length())
                plen = min(plen, self.max_seq)
                toks = np.zeros(plen, np.int32)
                toks[-len(req.prompt):] = req.prompt  # left-pad bucket
                logits, self.caches = self._prefill(
                    self.params, self.caches, jnp.asarray(toks), i, plen=plen)
                self.slots[i] = req
                self.pos[i] = plen
                self.budget[i] = req.max_new_tokens
                tok = int(np.argmax(np.asarray(logits)))
                req.output.append(tok)
                self.last_tok[i] = tok
                self.budget[i] -= 1

    def step(self):
        """One continuous-batching tick: admit, decode, retire."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return False
        self.key, sub = jax.random.split(self.key)
        toks = jnp.asarray(self.last_tok[:, None])
        nxt, self.caches = self._decode(self.params, self.caches, toks,
                                        jnp.asarray(self.pos), sub)
        nxt = np.asarray(nxt)
        for i in active:
            if self.pos[i] + 1 >= self.max_seq:
                self.budget[i] = 0
            else:
                self.slots[i].output.append(int(nxt[i]))
                self.last_tok[i] = nxt[i]
                self.pos[i] += 1
                self.budget[i] -= 1
            if self.budget[i] <= 0:
                self.slots[i].done = True
                self.slots[i] = None  # retire -> slot refillable next tick
        return True

    def run_to_completion(self, max_ticks: int = 10000):
        ticks = 0
        while (any(s is not None for s in self.slots)
               or not self.queue.empty()):
            if not self.step():
                break
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("serving did not converge")
        return ticks
