"""Token samplers: greedy / temperature / top-k / top-p, jit-friendly."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(key, logits, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0):
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    if top_p < 1.0:
        sorted_lf = jnp.sort(lf, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_lf, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_lf, cutoff_idx, axis=-1)
        lf = jnp.where(lf < cutoff, -jnp.inf, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
