"""Token samplers: greedy / temperature / top-k / top-p, jit-friendly.

One masking implementation serves every caller:

  * static python scalars — ``temperature <= 0`` still short-circuits to
    argmax at trace time (no sort, no PRNG use).  Any other static
    combination is broadcast into the vectorized path below, so the two
    entry modes can never diverge (they used to: the old scalar path fed
    ``top_k`` straight to ``jax.lax.top_k`` and crashed on ``top_k > V``
    while the vectorized path clipped it).
  * array-valued per-slot params — ``temperature``/``top_k``/``top_p`` may
    be [B] arrays (or traced scalars), one entry per batch slot.  Every
    slot is masked independently inside one jitted program: the
    continuous-batching engine runs a pool where each request carries its
    own sampling config, so the decode scan cannot branch on python
    values.  Sentinels: ``temperature <= 0`` means greedy for that slot,
    ``top_k == 0`` means no top-k, ``top_p >= 1`` means no nucleus cut.

``mask_logits`` is exposed on its own because speculative decoding needs
the *distributions*, not just a draw: the accept/reject test compares the
target and draft probabilities after the slot's own masking, so both
models must be filtered by exactly the same rule the sampler uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _static_scalars(*vals) -> bool:
    return all(isinstance(v, (int, float)) for v in vals)


def mask_logits(logits, *, temperature=0.0, top_k=0, top_p=1.0):
    """Temperature-scale then top-k/top-p mask logits, per row.

    logits [B, V] -> masked logits [B, V] (float32, ``-inf`` outside the
    kept set).  Params are scalars or [B] arrays with the module-doc
    sentinels.  Greedy rows (``temperature <= 0``) are scaled by 1 — their
    masked values are only meaningful to callers that handle greedy
    separately (``sample`` picks argmax of the raw logits for them).
    ``top_k`` is clipped to [1, V] so oversized values mean "disabled",
    never a crash.
    """
    lf = logits.astype(jnp.float32)
    b, v = lf.shape
    temp = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    tk = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (b,))
    tp = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))

    # temperature scale (guard greedy slots against /0)
    x = lf / jnp.where(temp > 0.0, temp, 1.0)[:, None]

    if _static_scalars(top_k, top_p) and top_k <= 0 and top_p >= 1.0:
        # trace-time: nothing to mask, no sort in the program at all
        return x

    def _full(x):
        # per-slot top-k: kth-highest value per row via a full descending
        # sort (lax.top_k needs a static k). top_k == 0 disables (k -> V);
        # any oversized k clips to V (disabled) instead of crashing.
        k_eff = jnp.clip(jnp.where(tk > 0, tk, v), 1, v)
        x_desc = jnp.sort(x, axis=-1)[..., ::-1]
        kth = jnp.take_along_axis(x_desc, (k_eff - 1)[:, None], axis=-1)
        xm = jnp.where(x < kth, -jnp.inf, x)

        # per-slot top-p on the top-k-masked logits (masked entries carry
        # zero probability mass). No second sort: the masked entries are
        # exactly the tail of x_desc, so the sorted masked array is x_desc
        # with positions >= n_kept set to -inf.
        n_kept = jnp.sum(x_desc >= kth, axis=-1, keepdims=True)
        x_desc = jnp.where(jnp.arange(v)[None, :] < n_kept, x_desc, -jnp.inf)
        probs = jax.nn.softmax(x_desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.clip(jnp.sum(cum < tp[:, None], axis=-1), 0, v - 1)
        cutoff = jnp.take_along_axis(x_desc, cutoff_idx[:, None], axis=-1)
        return jnp.where((xm < cutoff) & (tp[:, None] < 1.0), -jnp.inf, xm)

    # Runtime fast path: when NO row actually cuts (top_k disabled-or-
    # oversized and top_p disabled everywhere), the full path above is an
    # exact no-op — the kth value is the row min and the top_p cutoff is
    # gated by ``tp < 1`` — so skipping it is bitwise identical. The XLA
    # CPU sort is the single most expensive op in the decode step for
    # greedy pools (the speculative path masks K draft + K+1 verify
    # positions per step), which makes this branch worth a lax.cond.
    off = jnp.all(((tk <= 0) | (tk >= v)) & (tp >= 1.0))
    return jax.lax.cond(off, lambda x: x, _full, x)


def sample(key, logits, *, temperature=0.0, top_k=0, top_p=1.0):
    """logits [B, V] -> tokens [B].

    ``temperature``/``top_k``/``top_p`` are python scalars (static path) or
    [B] arrays / traced scalars (vectorized per-slot path, see module doc).
    """
    lf = logits.astype(jnp.float32)
    if _static_scalars(temperature, top_k, top_p) and temperature <= 0.0:
        # trace-time greedy: no sort, no PRNG consumption
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)

    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    temp = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32),
                            (lf.shape[0],))

    def _stoch(key):
        x = mask_logits(lf, temperature=temperature, top_k=top_k, top_p=top_p)
        return jax.random.categorical(key, x, axis=-1).astype(jnp.int32)

    # all-greedy pools skip masking + categorical at runtime; the final
    # where() picks ``greedy`` for those rows either way, so the fast
    # branch cannot change any output
    sampled = jax.lax.cond(jnp.all(temp <= 0.0), lambda _: greedy,
                           _stoch, key)
    return jnp.where(temp <= 0.0, greedy, sampled)
