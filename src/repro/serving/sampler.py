"""Token samplers: greedy / temperature / top-k / top-p, jit-friendly.

Two entry modes through one function:

  * static python scalars — the historical path: ``temperature <= 0`` short-
    circuits to argmax at trace time (no sort, no PRNG use), top-k/top-p are
    applied only when enabled.  This is what single-request callers and the
    greedy decode fast path use.
  * array-valued per-slot params — ``temperature``/``top_k``/``top_p`` may be
    [B] arrays (or traced scalars), one entry per batch slot.  Every slot is
    masked independently inside one jitted program: the continuous-batching
    engine runs a pool where each request carries its own sampling config,
    so the decode scan cannot branch on python values.  Disabled knobs use
    the same sentinels as the scalar path: ``temperature <= 0`` means greedy
    for that slot, ``top_k == 0`` means no top-k, ``top_p >= 1`` means no
    nucleus cut.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _static_scalars(*vals) -> bool:
    return all(isinstance(v, (int, float)) for v in vals)


def _sample_static(key, lf, temperature, top_k, top_p):
    """Historical scalar path (trace-time branching)."""
    if temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    lf = lf / temperature
    if top_k:
        kth = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    if top_p < 1.0:
        sorted_lf = jnp.sort(lf, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_lf, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_lf, cutoff_idx, axis=-1)
        lf = jnp.where(lf < cutoff, -jnp.inf, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def sample(key, logits, *, temperature=0.0, top_k=0, top_p=1.0):
    """logits [B, V] -> tokens [B].

    ``temperature``/``top_k``/``top_p`` are python scalars (static path) or
    [B] arrays / traced scalars (vectorized per-slot path, see module doc).
    """
    lf = logits.astype(jnp.float32)
    if _static_scalars(temperature, top_k, top_p):
        return _sample_static(key, lf, temperature, top_k, top_p)

    b, v = lf.shape
    temp = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    tk = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (b,))
    tp = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))

    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    # temperature scale (guard the greedy slots against /0; their sampled
    # value is discarded by the final select)
    x = lf / jnp.where(temp > 0.0, temp, 1.0)[:, None]

    # per-slot top-k: kth-highest value per row via a full descending sort
    # (lax.top_k needs a static k). top_k == 0 disables (k -> V).
    k_eff = jnp.clip(jnp.where(tk > 0, tk, v), 1, v)
    x_desc = jnp.sort(x, axis=-1)[..., ::-1]
    kth = jnp.take_along_axis(x_desc, (k_eff - 1)[:, None], axis=-1)
    x = jnp.where(x < kth, -jnp.inf, x)

    # per-slot top-p on the top-k-masked logits (masked entries carry zero
    # probability mass, matching the scalar path's apply order). No second
    # sort: the masked entries are exactly the tail of x_desc, so the sorted
    # masked array is x_desc with positions >= n_kept set to -inf.
    n_kept = jnp.sum(x_desc >= kth, axis=-1, keepdims=True)
    x_desc = jnp.where(jnp.arange(v)[None, :] < n_kept, x_desc, -jnp.inf)
    probs = jax.nn.softmax(x_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.clip(jnp.sum(cum < tp[:, None], axis=-1), 0, v - 1)
    cutoff = jnp.take_along_axis(x_desc, cutoff_idx[:, None], axis=-1)
    x = jnp.where((x < cutoff) & (tp[:, None] < 1.0), -jnp.inf, x)

    sampled = jax.random.categorical(key, x, axis=-1).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)
