"""Per-slot decoding modes for the continuous-batching engine.

The engine's decode scan runs ONE jitted program for the whole slot pool,
so a request's decoding strategy must be (a) ordinary per-slot device
state, like its sampling params, and (b) free of python control flow at
step granularity. This module owns both halves:

  * the **mode registry** — ``parse("beam:4")`` / ``parse("spec:draft2b")``
    turn a request's ``decoding`` string into a :class:`DecodingMode`, and
    the mode *kind* is the integer the engine carries in
    ``EngineState.mode`` ([B] i32);
  * the **pure step helpers** — ``beam_select`` (one beam expansion over
    the pool, fully vectorized, no per-group loops) and
    ``speculative_accept`` (Leviathan-style rejection sampling over a
    drafted token block, with the greedy path reduced to exact argmax
    agreement so greedy speculation is bit-exact with plain greedy).

Kinds:
  * ``NORMAL`` — greedy/temperature/top-k/top-p sampling, one token per
    scan step (the engine's historical behaviour).
  * ``BEAM``   — width-W beam search. The W hypotheses occupy W pool
    slots sharing a ``beam_group`` id; each step every member slot is
    reassigned to the globally best W continuations of the group
    (``beam_select``), and the engine forks caches to match. Beam search
    maximizes log-likelihood, so the slot's sampling params are ignored.
  * ``SPEC``   — self-speculative decoding. The draft model is the SAME
    packed weight tensor reinterpreted at a lower plane count
    (``models.quantized.plane_sliced_params`` — paper §3.1.2: a B-bit
    packed weight is exactly a sum of ±1 bit-planes, so the top planes
    are a free coarser model, zero extra weight HBM). The engine drafts
    K tokens with the sliced view, verifies all of them plus a bonus
    token in one s=K+1 target forward, and accepts the longest exact /
    rejection-sampled prefix.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["NORMAL", "BEAM", "SPEC", "DecodingMode", "parse",
           "beam_select", "speculative_accept", "rank_hypotheses"]

NORMAL, BEAM, SPEC = 0, 1, 2

_NEG = -1e30  # finite -inf stand-in: survives top_k and float adds


@dataclasses.dataclass(frozen=True)
class DecodingMode:
    """Parsed decoding request: kind + its static hyperparameters."""
    kind: int
    name: str
    beam_width: int = 1          # BEAM: number of pool slots the group owns
    draft_planes: int = 0        # SPEC: planes kept in the sliced draft view

    def __post_init__(self):
        if self.kind == BEAM and self.beam_width < 1:
            raise ValueError(f"beam width must be >= 1, got {self.beam_width}")
        if self.kind == SPEC and self.draft_planes < 1:
            raise ValueError(
                f"spec draft needs >= 1 plane, got {self.draft_planes}")


def parse(spec: str) -> DecodingMode:
    """Parse a request/CLI decoding string into a :class:`DecodingMode`.

    Grammar: ``greedy`` | ``sample`` | ``beam[:W]`` | ``spec[:draft<N>b]``
    (also accepts bare ``spec:N``). Defaults: beam width 4, draft 2 planes.
    """
    s = spec.strip().lower()
    head, _, arg = s.partition(":")
    if head in ("greedy", "sample"):
        if arg:
            raise ValueError(f"decoding {spec!r}: {head} takes no argument")
        return DecodingMode(NORMAL, head)
    if head == "beam":
        return DecodingMode(BEAM, "beam", beam_width=int(arg) if arg else 4)
    if head == "spec":
        if arg:
            m = arg
            if m.startswith("draft"):
                m = m[len("draft"):]
            if m.endswith("b"):
                m = m[:-1]
            planes = int(m)
        else:
            planes = 2
        return DecodingMode(SPEC, "spec", draft_planes=planes)
    raise ValueError(f"unknown decoding mode {spec!r} "
                     "(expected greedy | sample | beam[:W] | spec[:draftNb])")


# ---------------------------------------------------------------------------
# beam search: one expansion step over the whole pool
# ---------------------------------------------------------------------------

def beam_select(cum_score, logp, live, group):
    """One beam expansion for every beam group in the pool, vectorized.

    Args:
      cum_score: [B] f32 cumulative hypothesis log-prob per slot.
      logp:      [B, V] f32 log-softmax of this step's logits.
      live:      [B] bool — slot holds a still-expanding beam hypothesis.
      group:     [B] i32 beam-group id (the leader's slot index); < 0 for
                 slots that are not beam members.

    Returns ``(parent, token, score)``, each [B]: live slot ``b`` becomes
    the ``r``-th best continuation of its group, where ``r`` is ``b``'s
    rank among the group's live slots (a stable, collision-free assignment
    decided purely from indices — every member computes the same candidate
    list, then picks its own rank). Non-live slots return themselves with
    an unchanged score.

    The candidate list is exact: each live slot contributes its top-``Wmax``
    (``Wmax = min(B, V)``) continuations, and a group has at most B live
    members needing at most B winners, so winner ``r < B <= Wmax`` can
    always be served even if one parent supplies every winner.
    """
    b, v = logp.shape
    wmax = min(b, v)
    total = jnp.where(live[:, None], cum_score[:, None] + logp, _NEG)
    vals, toks = jax.lax.top_k(total, wmax)            # [B, Wmax]

    same = (group[:, None] == group[None, :]) & (group[:, None] >= 0)
    same = same & live[None, :]                        # [B, B] b's live peers
    # candidate matrix per slot: peers' top-Wmax, others masked out
    cand = jnp.where(same[:, :, None], vals[None, :, :], _NEG)
    cand = cand.reshape(b, b * wmax)
    cvals, cidx = jax.lax.top_k(cand, wmax)            # [B, Wmax] ranked

    # rank of slot b among its group's live slots (by index)
    rank = jnp.sum(same & (jnp.arange(b)[None, :] < jnp.arange(b)[:, None]),
                   axis=1)
    pick = jnp.take_along_axis(cidx, rank[:, None], axis=1)[:, 0]  # [B]
    parent_b = (pick // wmax).astype(jnp.int32)
    tok = toks[parent_b, pick % wmax].astype(jnp.int32)
    score = jnp.take_along_axis(cvals, rank[:, None], axis=1)[:, 0]

    self_idx = jnp.arange(b, dtype=jnp.int32)
    parent = jnp.where(live, parent_b, self_idx)
    token = jnp.where(live, tok, jnp.zeros_like(tok))
    score = jnp.where(live, score, cum_score)
    return parent, token, score


def rank_hypotheses(scores, lengths, alpha: float):
    """GNMT length-normalized final ranking: score / ((5+len)/6)^alpha.

    Host-side (numpy-friendly) helper used at beam-group retirement;
    ``alpha = 0`` reduces to raw cumulative log-prob.
    """
    import numpy as np
    scores = np.asarray(scores, np.float64)
    lengths = np.maximum(np.asarray(lengths, np.float64), 1.0)
    lp = ((5.0 + lengths) / 6.0) ** alpha
    return scores / lp


# ---------------------------------------------------------------------------
# self-speculation: accept/reject a drafted token block
# ---------------------------------------------------------------------------

def speculative_accept(key, draft_toks, q_logits, p_logits, tgt_raw_argmax,
                       greedy):
    """Leviathan/Chen rejection sampling over a drafted block, vectorized.

    Args:
      key:        PRNG key (consumed for accept coins + residual draws).
      draft_toks: [B, K] i32 tokens proposed by the draft view.
      q_logits:   [B, K, V] draft logits after the slot's own sampling mask
                  (``sampler.mask_logits``) — softmaxed here into q.
      p_logits:   [B, K+1, V] masked target logits for the same positions
                  plus the bonus position K — softmaxed here into p.
      tgt_raw_argmax: [B, K+1] i32 argmax of the RAW (unmasked, unscaled)
                  target logits. Greedy agreement/replacement uses this,
                  not argmax(p): plain greedy decode takes argmax of raw
                  logits, and re-deriving it through a softmax could round
                  two near-ties onto the same float and flip the winner —
                  bit-exactness demands the identical reduction.
      greedy:     [B] bool — slot decodes greedily (temperature <= 0).

    Returns ``(accept, repl, bonus)``:
      accept [B, K] bool — draft token j survives verification;
      repl   [B, K] i32  — the token to emit at the first rejected j
                           (exact residual draw, or argmax for greedy);
      bonus  [B] i32     — the free K-th token when every draft survives.

    Greedy slots use exact argmax agreement (accept iff the draft token IS
    the target argmax, replacement IS the target argmax), which makes the
    emitted chain identical to plain greedy decoding token-for-token. For
    stochastic slots the emitted tokens are distributed exactly as the
    target's masked distribution (accept w.p. min(1, p/q), residual
    ``max(p-q, 0)`` renormalized).
    """
    bsz, k, v = q_logits.shape
    tgt_argmax = tgt_raw_argmax[:, :k]                  # [B, K]
    acc_greedy = draft_toks == tgt_argmax

    def _greedy_only(key):
        # every slot is greedy: accept is exact argmax agreement, the
        # replacement IS the target argmax, no distribution work at all.
        # The full branch computes the same values for greedy rows (its
        # final where() picks the argmax side), so runtime-skipping the
        # softmax/categorical machinery cannot change any output.
        return acc_greedy, tgt_argmax, tgt_raw_argmax[:, k]

    def _full(key):
        kc, kr, kb = jax.random.split(key, 3)
        p_dist = jax.nn.softmax(p_logits, axis=-1)      # [B, K+1, V]
        q_dist = jax.nn.softmax(q_logits, axis=-1)      # [B, K, V]
        p_k = p_dist[:, :k, :]

        p_tok = jnp.take_along_axis(p_k, draft_toks[..., None],
                                    axis=-1)[..., 0]
        q_tok = jnp.take_along_axis(q_dist, draft_toks[..., None],
                                    axis=-1)[..., 0]
        u = jax.random.uniform(kc, (bsz, k))
        acc_stoch = u * jnp.maximum(q_tok, 1e-30) < p_tok
        accept = jnp.where(greedy[:, None], acc_greedy, acc_stoch)

        # residual distribution max(p - q, 0); exactly-zero residual
        # (p == q) falls back to p so the draw stays well-defined
        res = jnp.maximum(p_k - q_dist, 0.0)
        res_mass = jnp.sum(res, axis=-1, keepdims=True)
        res = jnp.where(res_mass > 0.0,
                        res / jnp.maximum(res_mass, 1e-30), p_k)
        r_stoch = jax.random.categorical(
            kr, jnp.log(jnp.maximum(res, 1e-30)), axis=-1).astype(jnp.int32)
        repl = jnp.where(greedy[:, None], tgt_argmax, r_stoch)

        bonus_stoch = jax.random.categorical(
            kb, jnp.log(jnp.maximum(p_dist[:, k, :], 1e-30)),
            axis=-1).astype(jnp.int32)
        bonus = jnp.where(greedy, tgt_raw_argmax[:, k], bonus_stoch)
        return accept, repl, bonus

    return jax.lax.cond(jnp.all(greedy), _greedy_only, _full, key)
