"""Host-side bookkeeping for the block-paged KV cache pool.

The device holds one pool of fixed-size cache blocks (kvcache.paged_gather /
paged_scatter); this module owns which block belongs to whom:

  * :class:`BlockAllocator` — a refcounted free list over block ids
    ``1..num_blocks-1``. Block 0 is the reserved NULL block: idle and
    retired slots point their whole page-table row at it so their masked-out
    decode writes land somewhere harmless, and it is never handed out.
    Shared-prefix blocks are plain refcounts: each slot referencing a block
    holds one ref, the prefix cache holds one more, and the block returns to
    the free list when the last ref drops.

  * :class:`PrefixCache` — hash-chain shared-prefix index (vLLM-style).
    Key for block ``j`` of a prompt is a digest of ``tokens[:(j+1)*bs]``:
    causal attention makes a block's K/V content a pure function of every
    token up to its end, so equal chain keys mean bit-identical block
    contents. Entries hold one allocator ref and are LRU-evicted when the
    free list runs dry.

Sharing discipline (enforced by the engine, documented here because the
key scheme encodes it): decode writes start at position ``plen - 1`` (the
last prompt token's K/V is written by the first decode step), so a block is
shared READ-ONLY only when it lies entirely below that — ``(j+1)*bs <=
plen-1``. The divergence block that ends exactly at ``plen`` is instead
copy-on-write: its cached content is device-copied into a private block,
and the first decode step overwrites position ``plen-1`` in the copy.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional

import numpy as np

__all__ = ["BlockAllocator", "PrefixCache", "chain_key", "NULL_BLOCK"]

NULL_BLOCK = 0


def chain_key(tokens) -> bytes:
    """Digest of a token prefix — the chain hash for the block ending at
    ``len(tokens)``. Equal keys imply bit-identical block K/V (causality)."""
    t = np.ascontiguousarray(np.asarray(tokens, np.int32))
    return hashlib.sha256(t.tobytes()).digest()


class BlockAllocator:
    """Refcounted free-list allocator over pool block ids (1-based; block 0
    is the null block and never allocated).

    When a ``repro.obs.metrics.MetricsRegistry`` is supplied, grants /
    denials / frees flow into ``blockpool_*`` counters and the in-use gauge
    tracks the free list — the same numbers ``engine.stats()`` reports, but
    scrapeable mid-run without calling stats().
    """

    def __init__(self, num_blocks: int, metrics=None):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 null + 1 usable), "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        # pop() hands out ascending ids — purely cosmetic, but it makes
        # allocation traces readable
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.refs: Dict[int, int] = {}
        self._m_granted = self._m_denied = self._m_freed = None
        self._m_in_use = None
        if metrics is not None:
            self._m_granted = metrics.counter(
                "blockpool_blocks_granted_total",
                help="cache blocks handed out by alloc()")
            self._m_denied = metrics.counter(
                "blockpool_alloc_denied_total",
                help="all-or-nothing alloc() calls denied for lack of blocks")
            self._m_freed = metrics.counter(
                "blockpool_blocks_freed_total",
                help="blocks returned to the free list (last ref dropped)")
            self._m_in_use = metrics.gauge(
                "blockpool_blocks_in_use", help="pool blocks currently held")

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh blocks at refcount 1, or None if the free list is short
        (all-or-nothing: a partial grant could deadlock admission)."""
        if n > len(self._free):
            if self._m_denied is not None:
                self._m_denied.inc()
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self.refs[b] = 1
        if self._m_granted is not None:
            self._m_granted.inc(n)
            self._m_in_use.set(self.num_used)
        return blocks

    def incref(self, block: int) -> None:
        if block == NULL_BLOCK:
            return
        self.refs[block] += 1

    def decref(self, block: int) -> None:
        if block == NULL_BLOCK:
            return
        r = self.refs[block] - 1
        if r < 0:
            raise RuntimeError(f"double free of block {block}")
        if r == 0:
            del self.refs[block]
            self._free.append(block)
            if self._m_freed is not None:
                self._m_freed.inc()
                self._m_in_use.set(self.num_used)
        else:
            self.refs[block] = r


class PrefixCache:
    """LRU chain-hash index: ``chain_key -> block id``. Each entry holds one
    allocator ref, so a cached block survives its origin slot's retirement and is
    reclaimed only by eviction (or never, while other slots still share it).
    """

    def __init__(self, allocator: BlockAllocator, metrics=None):
        self._alloc = allocator
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._m_hits = self._m_misses = self._m_evictions = None
        if metrics is not None:
            self._m_hits = metrics.counter(
                "prefix_cache_hits_total",
                help="prefix-block lookups served from the chain-hash index")
            self._m_misses = metrics.counter("prefix_cache_misses_total")
            self._m_evictions = metrics.counter(
                "prefix_cache_evictions_total",
                help="LRU entries dropped to refill the free list")

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: bytes) -> Optional[int]:
        """Block id for ``key`` or None. Hit refreshes LRU recency; the
        caller increfs for its own use."""
        bid = self._entries.get(key)
        if bid is None:
            self.misses += 1
            if self._m_misses is not None:
                self._m_misses.inc()
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        if self._m_hits is not None:
            self._m_hits.inc()
        return bid

    def put(self, key: bytes, block: int) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        self._alloc.incref(block)
        self._entries[key] = block

    def evict_until(self, n_free: int) -> int:
        """Drop LRU entries until the allocator has ``n_free`` free blocks
        or the cache is empty. Entries whose block is still shared by live
        slots lose shareability but free nothing until those slots retire."""
        dropped = 0
        while self._alloc.num_free < n_free and self._entries:
            _, bid = self._entries.popitem(last=False)
            self._alloc.decref(bid)
            self.evictions += 1
            if self._m_evictions is not None:
                self._m_evictions.inc()
            dropped += 1
        return dropped
