"""Pallas TPU kernel: dequantization-based mpGEMM (paper Fig. 2b baseline).

What a stock MAC datapath must do with low-bit weights: stream the packed
codes, *upcast them to the activation dtype in-core*, then run a dense GEMM.
Weight HBM traffic is identical to the LUT kernel (both stream the packed
B-bit format); the difference is on-chip: this kernel pays the unpack +
sign-reconstruct + int→float convert on the VPU and contracts A directly,
while the LUT kernel amortizes K-element groups through the table.

Shares the folded-storage format (Eq. 6): raw plane bits are recovered as
``bit_i = idx_i XOR sign`` for i < K-1 and ``bit_{K-1} = sign``.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["dequant_mpgemm_pallas"]


def _unpack_w(packed_blk, *, k_group: int, planes: int,
              plane_scales: Sequence[float], bn: int, bg: int):
    """uint8 [bn, bg*B*K/8] -> reinterpreted weights q' [bn, bg*k_group] f32."""
    fpb = 8 // k_group
    mask = (1 << k_group) - 1
    lowmask = (1 << (k_group - 1)) - 1
    x = packed_blk.astype(jnp.int32)
    shifts = (k_group * jnp.arange(fpb, dtype=jnp.int32))
    fields = (x[:, :, None] >> shifts[None, None, :]) & mask
    fields = fields.reshape(bn, bg, planes)
    sign = fields >> (k_group - 1)
    idx = fields & lowmask
    w = jnp.zeros((bn, bg, k_group), jnp.float32)
    for i in range(k_group - 1):
        bit = ((idx >> i) & 1) ^ sign  # unfold Eq. 6
        sigma = (2 * bit - 1).astype(jnp.float32)
        qp = jnp.zeros((bn, bg), jnp.float32)
        for b in range(planes):
            qp = qp + float(plane_scales[b]) * sigma[:, :, b]
        w = w.at[:, :, i].set(qp)
    sigma_msb = (2 * sign - 1).astype(jnp.float32)
    qp = jnp.zeros((bn, bg), jnp.float32)
    for b in range(planes):
        qp = qp + float(plane_scales[b]) * sigma_msb[:, :, b]
    w = w.at[:, :, k_group - 1].set(qp)
    return w.reshape(bn, bg * k_group)


def _kernel(a_ref, pk_ref, ws_ref, o_ref, acc_ref, *, k_group: int,
            planes: int, plane_scales, bn: int, bg: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_w(pk_ref[...], k_group=k_group, planes=planes,
                  plane_scales=plane_scales, bn=bn, bg=bg)  # [bn, bk]
    a = a_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...] * ws_ref[...]


def dequant_mpgemm_pallas(
    a: jax.Array,            # [M, K_total]
    packed: jax.Array,       # [N, G*B*k_group/8] uint8
    wscale: jax.Array,       # [N]
    *,
    k_group: int,
    planes: int,
    plane_scales: Sequence[float],
    n: int,
    block_m: int = 64,
    block_n: int = 256,
    block_g: int = 64,
    interpret: bool = False,
) -> jax.Array:
    m, k_total = a.shape
    g = k_total // k_group
    assert m % block_m == 0 and n % block_n == 0 and g % block_g == 0
    pb_blk = block_g * planes * k_group // 8
    grid = (m // block_m, n // block_n, g // block_g)
    kern = functools.partial(_kernel, k_group=k_group, planes=planes,
                             plane_scales=tuple(map(float, plane_scales)),
                             bn=block_n, bg=block_g)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_g * k_group), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, pb_blk), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, packed, wscale.reshape(1, n).astype(jnp.float32))
