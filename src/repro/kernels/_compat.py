"""Version compatibility for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and the
old name back again in some releases); every kernel in this package routes
through :data:`CompilerParams` so a single alias tracks whichever spelling
the installed jax exposes.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
