"""Pallas TPU kernel: LUT table precompute (+ fused INT8 table quantization).

The DFG-transformed precompute operator (§3.1.1) as a standalone kernel:
activations stream HBM→VMEM once, each [bm, bg·K] block is contracted with
the ±1 sign basis on the MXU to produce the [bm, bg·E] half-table block, and
(optionally) quantized to INT8 in-VMEM before the store — so the table that
lands in HBM is already LUT_BIT=8 (Eq. 7's table-size term).

Per-row scales are computed from A in closed form (Σ|a_i| per group, maxed
over groups — see table.group_absmax) by the wrapper and passed in, so this
kernel stays a single pass.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["table_precompute_pallas"]


def _sign_basis_iota(k_group: int):
    """±1 basis [K, E] built from iota (pallas kernels cannot capture consts)."""
    e = 1 << (k_group - 1)
    ent = jax.lax.broadcasted_iota(jnp.int32, (k_group, e), 1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (k_group, e), 0)
    bit = (ent >> pos) & 1
    basis = jnp.where(pos == k_group - 1, -1.0,
                      2.0 * bit.astype(jnp.float32) - 1.0)
    return basis


def _kernel(a_ref, ts_ref, tq_ref, *, k_group: int, bm: int, bg: int,
            mode: Optional[str]):
    e = 1 << (k_group - 1)
    a = a_ref[...].astype(jnp.float32).reshape(bm, bg, k_group)
    basis = _sign_basis_iota(k_group)  # [K, E], materialized in VMEM
    ent = jax.lax.dot_general(
        a.reshape(bm * bg, k_group), basis, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bm, bg, e)
    if mode is None:
        tq_ref[...] = ent.reshape(bm, bg * e)
        return
    if mode == "per_group":
        absmax = jnp.sum(jnp.abs(a), axis=-1)  # [bm, bg] closed form
        scale = jnp.maximum(absmax, 1e-30) / 127.0
        ts_ref[...] = scale
        q = ent / scale[:, :, None]
    else:  # per_row: scale computed by wrapper, streamed in
        q = ent / ts_ref[...].reshape(bm, 1, 1)
    tq_ref[...] = jnp.clip(jnp.round(q), -127, 127).astype(jnp.int8).reshape(
        bm, bg * e)


def table_precompute_pallas(
    a: jax.Array,             # [M, K_total] (pre-padded to blocks)
    k_group: int,
    table_quant: Optional[str],
    row_scale: Optional[jax.Array] = None,  # [M, 1] f32, required for per_row
    *,
    block_m: int = 64,
    block_g: int = 128,
    interpret: bool = False,
):
    """Returns (values [M, G*E], scale or None). Rowsum is wrapper-side."""
    m, k_total = a.shape
    g = k_total // k_group
    e = 1 << (k_group - 1)
    assert m % block_m == 0 and g % block_g == 0, ((m, g), (block_m, block_g))
    grid = (m // block_m, g // block_g)
    kern = functools.partial(_kernel, k_group=k_group, bm=block_m, bg=block_g,
                             mode=table_quant)
    out_dtype = jnp.float32 if table_quant is None else jnp.int8

    in_specs = [pl.BlockSpec((block_m, block_g * k_group), lambda i, k: (i, k))]
    if table_quant == "per_row":
        assert row_scale is not None
        in_specs.append(pl.BlockSpec((block_m, 1), lambda i, k: (i, 0)))
        ts_arg = row_scale.astype(jnp.float32)
        out_specs = pl.BlockSpec((block_m, block_g * e), lambda i, k: (i, k))
        out_shape = jax.ShapeDtypeStruct((m, g * e), out_dtype)
        values = pl.pallas_call(
            kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(a, ts_arg)
        return values, row_scale
    if table_quant == "per_group":
        out_specs = [
            pl.BlockSpec((block_m, block_g), lambda i, k: (i, k)),      # scale
            pl.BlockSpec((block_m, block_g * e), lambda i, k: (i, k)),  # values
        ]
        out_shape = [
            jax.ShapeDtypeStruct((m, g), jnp.float32),
            jax.ShapeDtypeStruct((m, g * e), out_dtype),
        ]

        def kern2(a_ref, ts_ref, tq_ref):
            kern(a_ref, ts_ref, tq_ref)

        scale, values = pl.pallas_call(
            kern2, grid=grid, in_specs=in_specs[:1], out_specs=out_specs,
            out_shape=out_shape,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(a)
        return values, scale
    # float table
    out_specs = pl.BlockSpec((block_m, block_g * e), lambda i, k: (i, k))
    out_shape = jax.ShapeDtypeStruct((m, g * e), out_dtype)

    def kern3(a_ref, tq_ref):
        kern(a_ref, None, tq_ref)

    values = pl.pallas_call(
        kern3, grid=grid, in_specs=in_specs[:1], out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a)
    return values, None
