"""Pallas TPU kernel: LUT-based mpGEMM (the LUT Tensor Core datapath).

Realizes the paper's LUT array (§3.2) on the TPU memory hierarchy:

  * the per-(row, group) half-table lives in **VMEM** (the analogue of the
    paper's table registers), streamed in [bm, bg·E] blocks;
  * packed B-bit weight codes stream from HBM in their true packed form —
    ``bg·B·k_group/8`` bytes per N-row per K-block — this is the 4–16×
    weight-traffic reduction the co-design banks on;
  * the lookup itself runs on the **MXU**: the packed codes are expanded
    in-VMEM to the combined-lookup matrix CW (one-hot × plane scales ×
    Eq.-6 sign, values in [-15, 15] ⇒ int8) and contracted against the
    table block.  With int8 tables (table quantization, §3.1.3) the MXU
    runs at its 2× int8 rate;
  * bit-serial (§3.2.1) is folded into CW: all B planes of a group share
    the table and collapse into one int8 coefficient per entry;
  * the elongated tiling (§3.2.2) appears as bn ≫ bm block shapes chosen
    by the LMMA tile scheduler (lmma.schedule_tiles).

Grid: (M/bm, N/bn, G/bg), K innermost with VMEM scratch accumulation.
Variants: int path (per-row-quantized int8 tables, int32 accumulate) and
f32 path (float tables, or per-group scales dequantized in-VMEM).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["lut_mpgemm_pallas"]


def _unpack_cw(packed_blk, *, k_group: int, planes: int, plane_scales: Tuple[int, ...],
               bn: int, bg: int, acc_dtype):
    """uint8 [bn, bg*B*K/8] -> CW [bn, bg*E] (int8-valued, cast to acc side).

    fields(g, b) are group-major, k_group-bit, little-endian within bytes.
    """
    e = 1 << (k_group - 1)
    fpb = 8 // k_group
    mask = (1 << k_group) - 1
    lowmask = e - 1
    x = packed_blk.astype(jnp.int32)  # [bn, PB]
    shifts = (k_group * jnp.arange(fpb, dtype=jnp.int32))
    fields = (x[:, :, None] >> shifts[None, None, :]) & mask  # [bn, PB, fpb]
    fields = fields.reshape(bn, bg * planes)  # group-major: g*B + b
    fields = fields.reshape(bn, bg, planes)
    sign = fields >> (k_group - 1)             # {0,1}
    idx = fields & lowmask                     # [0, E)
    coeff = (1 - 2 * sign)                     # ±1
    ent = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, e), 3)
    onehot = (idx[..., None] == ent)           # [bn, bg, B, E] bool
    cw = jnp.zeros((bn, bg, e), jnp.int32)
    for b in range(planes):  # bit-serial: planes share the table (§3.2.1)
        cw = cw + int(plane_scales[b]) * jnp.where(onehot[:, :, b, :],
                                                   coeff[:, :, b:b + 1], 0)
    return cw.reshape(bn, bg * e).astype(acc_dtype)


def _kernel_int(tv_ref, ts_ref, pk_ref, ws_ref, o_ref, acc_ref, *,
                k_group: int, planes: int, plane_scales, bn: int, bg: int):
    """int8 tables, per-row scale: exact int32 accumulation over the K grid."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cw = _unpack_cw(pk_ref[...], k_group=k_group, planes=planes,
                    plane_scales=plane_scales, bn=bn, bg=bg, acc_dtype=jnp.int8)
    # MXU int8 contraction: [bm, bg*E] x [bn, bg*E]^T -> [bm, bn] int32
    acc_ref[...] += jax.lax.dot_general(
        tv_ref[...], cw, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        # per-row table scale x per-channel weight scale
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * ts_ref[...] * ws_ref[...])


def _kernel_f32(tv_ref, ts_ref, pk_ref, ws_ref, o_ref, acc_ref, *,
                k_group: int, planes: int, plane_scales, bn: int, bg: int,
                per_group: bool, bm: int):
    """float tables (or int8 + per-group scales dequantized in-VMEM)."""
    k = pl.program_id(2)
    e = 1 << (k_group - 1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tv = tv_ref[...]
    if per_group:
        tv = (tv.astype(jnp.float32).reshape(bm, bg, e)
              * ts_ref[...].reshape(bm, bg, 1)).reshape(bm, bg * e)
    else:
        tv = tv.astype(jnp.float32)
    cw = _unpack_cw(pk_ref[...], k_group=k_group, planes=planes,
                    plane_scales=plane_scales, bn=bn, bg=bg,
                    acc_dtype=jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        tv, cw, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...] * ws_ref[...]


def lut_mpgemm_pallas(
    tv: jax.Array,            # [M, G*E] table values (int8 or f32)
    ts: Optional[jax.Array],  # [M, 1] per-row | [M, G] per-group | None
    packed: jax.Array,        # [N, G*B*k_group/8] uint8
    wscale: jax.Array,        # [N] f32
    *,
    k_group: int,
    planes: int,
    plane_scales: Sequence[float],
    n: int,
    block_m: int = 8,
    block_n: int = 256,
    block_g: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Launch the LUT mpGEMM kernel. Shapes must be pre-padded to blocks."""
    m, ge = tv.shape
    e = 1 << (k_group - 1)
    g = ge // e
    assert m % block_m == 0 and n % block_n == 0 and g % block_g == 0, (
        (m, n, g), (block_m, block_n, block_g))
    pb_blk = block_g * planes * k_group // 8
    assert block_g * planes * k_group % 8 == 0, "K-block must be byte aligned"
    grid = (m // block_m, n // block_n, g // block_g)

    per_row = ts is not None and ts.shape[1] == 1
    per_group = ts is not None and ts.shape[1] == g
    plane_scales = tuple(float(s) for s in plane_scales)
    int_path = per_row and tv.dtype == jnp.int8

    ws2d = wscale.reshape(1, n).astype(jnp.float32)
    in_specs = [
        pl.BlockSpec((block_m, block_g * e), lambda i, j, k: (i, k)),  # table
    ]
    if per_row:
        ts_in = ts.astype(jnp.float32)
        in_specs.append(pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)))
    elif per_group:
        ts_in = ts.astype(jnp.float32)
        in_specs.append(pl.BlockSpec((block_m, block_g), lambda i, j, k: (i, k)))
    else:
        ts_in = jnp.ones((m, 1), jnp.float32)  # unused placeholder
        in_specs.append(pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)))
    in_specs += [
        pl.BlockSpec((block_n, pb_blk), lambda i, j, k: (j, k)),       # packed W
        pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),            # wscale
    ]

    if int_path:
        kern = functools.partial(_kernel_int, k_group=k_group, planes=planes,
                                 plane_scales=plane_scales, bn=block_n, bg=block_g)
        scratch = pltpu.VMEM((block_m, block_n), jnp.int32)
    else:
        kern = functools.partial(_kernel_f32, k_group=k_group, planes=planes,
                                 plane_scales=plane_scales, bn=block_n,
                                 bg=block_g, per_group=per_group, bm=block_m)
        scratch = pltpu.VMEM((block_m, block_n), jnp.float32)
        if tv.dtype == jnp.int8 and per_row:
            pass  # handled by int path above
        if not per_group and ts is not None and per_row:
            # f32 path with per-row scales: fold scale into output via ws?
            # simpler: pre-scale the table values outside (ops.py does this).
            raise ValueError("f32 path does not take per-row scales; "
                             "pre-scale tables in the wrapper")

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[scratch],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tv, ts_in, packed, ws2d)
    return out
