"""Pallas TPU kernel: fused precompute→lookup mpGEMM (§3.1.1, fused form).

The staged pipeline materializes the ``[M, G·E]`` half-table in HBM between
``table_precompute_pallas`` and ``lut_mpgemm_pallas`` — the indirect,
traffic-bound pattern the paper's DFG analysis says LUT methods must avoid
once the table stops fitting on-chip.  This kernel is the fused alternative:
one ``pallas_call`` whose grid streams **activation** blocks HBM→VMEM
(``bm·bg·K`` elements — an E/K-times smaller footprint than the table
block), rebuilds the ``[bm, bg·E]`` half-table block on the MXU in-VMEM via
the ±1 sign-basis contraction, optionally quantizes it to INT8 in-register
(per-row and per-group modes, §3.1.3), and immediately contracts it against
the combined-lookup matrix CW unpacked from the packed weight stream.  The
table never touches HBM.

Numerical contract (tests enforce it):

  * ``table_quant='per_row'`` — bit-exact with the staged composition: the
    per-group basis contraction has no cross-block reduction, the INT8
    quantization uses the same wrapper-computed closed-form row scale, and
    accumulation is exact int32.
  * ``table_quant=None | 'per_group'`` — float accumulation in the same
    K-block order as the staged kernel (same ``bg``), so parity holds to
    float tolerance.

Cost trade: the table block is recomputed once per (j, k) grid step instead
of being read back N/bn times; the recompute is an MXU contraction of depth
``k_group`` (≤8) — cheap — while the avoided HBM traffic is the full table
(≥ table_bits/(8·k_group)·E× the activation bytes) per N-tile pass.  The
LMMA scheduler (core/lmma.py: ``select_fusion``) picks fused whenever the
in-VMEM working set fits the budget.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.lut_mpgemm import _unpack_cw
from repro.kernels.table_precompute import _sign_basis_iota

__all__ = ["fused_lut_mpgemm_pallas"]


def _table_block(a_ref, *, k_group: int, bm: int, bg: int):
    """[bm, bg·K] activation block -> [bm, bg, E] f32 half-table block.

    Identical computation to table_precompute._kernel: a single MXU
    contraction against the iota-built ±1 basis. Contraction depth is
    k_group only, so the result is independent of grid blocking — this is
    what makes the fused path bit-compatible with the staged one.
    """
    e = 1 << (k_group - 1)
    a = a_ref[...].astype(jnp.float32).reshape(bm * bg, k_group)
    basis = _sign_basis_iota(k_group)  # [K, E]
    return jax.lax.dot_general(
        a, basis, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bm, bg, e)


def _kernel_int(a_ref, rs_ref, pk_ref, ws_ref, o_ref, acc_ref, *,
                k_group: int, planes: int, plane_scales, bm: int, bn: int,
                bg: int):
    """per_row INT8 tables built in-register; exact int32 accumulation."""
    k = pl.program_id(2)
    e = 1 << (k_group - 1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ent = _table_block(a_ref, k_group=k_group, bm=bm, bg=bg)
    q = ent / rs_ref[...].reshape(bm, 1, 1)
    tq = jnp.clip(jnp.round(q), -127, 127).astype(jnp.int8).reshape(
        bm, bg * e)
    cw = _unpack_cw(pk_ref[...], k_group=k_group, planes=planes,
                    plane_scales=plane_scales, bn=bn, bg=bg,
                    acc_dtype=jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        tq, cw, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * rs_ref[...] * ws_ref[...])


def _kernel_f32(a_ref, pk_ref, ws_ref, o_ref, acc_ref, *,
                k_group: int, planes: int, plane_scales, bm: int, bn: int,
                bg: int, per_group: bool):
    """float tables (mode None) or per-group INT8 quantize→dequantize."""
    k = pl.program_id(2)
    e = 1 << (k_group - 1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ent = _table_block(a_ref, k_group=k_group, bm=bm, bg=bg)
    if per_group:
        # closed-form scale max_e|T[e]| = Σ|a_i| (table.group_absmax)
        a = a_ref[...].astype(jnp.float32).reshape(bm, bg, k_group)
        scale = jnp.maximum(jnp.sum(jnp.abs(a), axis=-1), 1e-30) / 127.0
        q = jnp.clip(jnp.round(ent / scale[:, :, None]), -127, 127)
        ent = q * scale[:, :, None]  # dequantize in-register (carries the
        # §3.1.3 quantization error, matching the staged pipeline)
    tv = ent.reshape(bm, bg * e)
    cw = _unpack_cw(pk_ref[...], k_group=k_group, planes=planes,
                    plane_scales=plane_scales, bn=bn, bg=bg,
                    acc_dtype=jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        tv, cw, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...] * ws_ref[...]


def fused_lut_mpgemm_pallas(
    a: jax.Array,             # [M, K_total] activations (pre-padded)
    row_scale: Optional[jax.Array],  # [M, 1] f32 (per_row) | None
    packed: jax.Array,        # [N, G*B*k_group/8] uint8
    wscale: jax.Array,        # [N] f32
    *,
    k_group: int,
    table_quant: Optional[str],
    planes: int,
    plane_scales: Sequence[float],
    n: int,
    block_m: int = 8,
    block_n: int = 256,
    block_g: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Launch the fused kernel. Shapes must be pre-padded to blocks."""
    m, k_total = a.shape
    g = k_total // k_group
    assert m % block_m == 0 and n % block_n == 0 and g % block_g == 0, (
        (m, n, g), (block_m, block_n, block_g))
    assert block_g * planes * k_group % 8 == 0, "K-block must be byte aligned"
    pb_blk = block_g * planes * k_group // 8
    grid = (m // block_m, n // block_n, g // block_g)
    plane_scales = tuple(float(s) for s in plane_scales)
    ws2d = wscale.reshape(1, n).astype(jnp.float32)

    a_spec = pl.BlockSpec((block_m, block_g * k_group), lambda i, j, k: (i, k))
    pk_spec = pl.BlockSpec((block_n, pb_blk), lambda i, j, k: (j, k))
    ws_spec = pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))
    out_spec = pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j))

    if table_quant == "per_row":
        assert row_scale is not None, "per_row needs the wrapper's row scale"
        kern = functools.partial(
            _kernel_int, k_group=k_group, planes=planes,
            plane_scales=plane_scales, bm=block_m, bn=block_n, bg=block_g)
        in_specs = [a_spec,
                    pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
                    pk_spec, ws_spec]
        args = (a, row_scale.astype(jnp.float32), packed, ws2d)
        scratch = pltpu.VMEM((block_m, block_n), jnp.int32)
    elif table_quant in (None, "per_group"):
        kern = functools.partial(
            _kernel_f32, k_group=k_group, planes=planes,
            plane_scales=plane_scales, bm=block_m, bn=block_n, bg=block_g,
            per_group=table_quant == "per_group")
        in_specs = [a_spec, pk_spec, ws_spec]
        args = (a, packed, ws2d)
        scratch = pltpu.VMEM((block_m, block_n), jnp.float32)
    else:
        raise ValueError(f"unknown table_quant mode {table_quant!r}")

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[scratch],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
