"""Pure-jnp oracles for every Pallas kernel.

Three semantically-equivalent mpGEMM formulations (they must all agree to
float tolerance; tests enforce this):

  * ``ref_dequant_mpgemm``      — A @ dequantize(W).T, the paper's baseline.
  * ``ref_lut_mpgemm_gather``   — the *literal* paper mechanism: per K-group
    table lookup by folded index with MSB sign (Eq. 5-6), bit-serial over
    planes. O(M·G·B·N) gathers — the semantic ground truth.
  * ``ref_lut_mpgemm_matmul``   — the TPU-native reformulation: one GEMM
    ``T[M, G·E] @ CW[G·E, N]`` where CW folds one-hot lookup, per-plane
    2^b scales and the Eq.-6 sign into a static int8 matrix (DESIGN.md §2).

Also: ``ref_table_precompute`` (re-export of the core operator) and
``build_cw`` (the CW expansion used by both the XLA path and the kernel).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import table as table_mod
from repro.core.quantize import QuantizedWeight, dequantize
from repro.core.table import Table, precompute_table

__all__ = [
    "ref_table_precompute",
    "ref_dequant_mpgemm",
    "ref_lut_mpgemm_gather",
    "ref_lut_mpgemm_matmul",
    "build_cw",
    "zero_point_correction",
]

ref_table_precompute = precompute_table


def zero_point_correction(out, qw: QuantizedWeight, rowsum):
    """out[m,n] -= rowsum[m] * scale[n] * z'[n]  (no-op for symmetric)."""
    if qw.zero_prime is None:
        return out
    return out - jnp.outer(rowsum, qw.scale * qw.zero_prime)


def ref_dequant_mpgemm(a, qw: QuantizedWeight, out_dtype=jnp.float32):
    w = dequantize(qw)  # [N, K]
    return jnp.dot(a.astype(jnp.float32), w.T).astype(out_dtype)


def _lookup_plane(tvals, sign, idx):
    """tvals [M,G,E] f32, sign/idx [N,G] -> [M,G,N] looked-up (±)entries."""
    # gather along E with (n, g)-dependent index; ground-truth only (O(MGN)).
    gathered = jnp.take_along_axis(
        tvals[:, :, None, :],  # [M, G, 1, E]
        idx.T[None, :, :, None].astype(jnp.int32),  # [1, G, N, 1]
        axis=-1,
    )[..., 0]  # [M, G, N]
    s = 1.0 - 2.0 * sign.T[None].astype(jnp.float32)  # [1, G, N]
    return gathered * s


def ref_lut_mpgemm_gather(a, qw: QuantizedWeight,
                          table_quant: Optional[str] = None,
                          out_dtype=jnp.float32):
    """Literal per-group lookup, bit-serial over planes (paper Fig. 3/8)."""
    t = precompute_table(a, qw.k_group, table_quant)
    tvals = table_mod.dequantize_table(t)  # [M, G, E] f32
    sign, idx = qw.sign_idx()  # [N, G, B]
    acc = jnp.zeros((a.shape[0], qw.n), jnp.float32)
    ps = jnp.asarray(qw.plane_scales, jnp.float32)
    for b in range(qw.num_planes):  # bit-serial
        lk = _lookup_plane(tvals, sign[:, :, b], idx[:, :, b])  # [M,G,N]
        acc = acc + ps[b] * jnp.sum(lk, axis=1)
    out = acc * qw.scale[None, :]
    out = zero_point_correction(out, qw, t.rowsum)
    return out.astype(out_dtype)


def build_cw(qw: QuantizedWeight, dtype=jnp.int8):
    if qw.cw is not None:
        return qw.cw.astype(dtype)
    """Static combined-lookup weights CW [G*E, N].

    CW[(g,e), n] = Σ_b plane_scales[b] · (1-2·sign[n,g,b]) · [idx[n,g,b]==e].
    Integer plane scales (≤ Σ 2^b = 2^B-1 ≤ 15 for B≤4) keep CW exactly
    representable in int8 — this is what unlocks the int8 MXU path.
    """
    sign, idx = qw.sign_idx()  # [N, G, B]
    e = 1 << (qw.k_group - 1)
    onehot = (idx[..., None] == jnp.arange(e, dtype=idx.dtype)).astype(jnp.int32)
    coeff = (1 - 2 * sign.astype(jnp.int32)) * jnp.asarray(qw.plane_scales, jnp.int32)[None, None, :]
    cw = jnp.einsum("ngbe,ngb->nge", onehot, coeff)  # [N, G, E]
    n, g = qw.n, qw.g
    return jnp.transpose(cw, (1, 2, 0)).reshape(g * e, n).astype(dtype)


def ref_lut_mpgemm_matmul(a, qw: QuantizedWeight,
                          table_quant: Optional[str] = None,
                          table: Optional[Table] = None,
                          out_dtype=jnp.float32):
    """T @ CW single-GEMM formulation (accepts a precomputed/fused table)."""
    t = table if table is not None else precompute_table(a, qw.k_group, table_quant)
    m = a.shape[0]
    e = 1 << (qw.k_group - 1)
    if t.scale is None:
        tv = t.values.reshape(m, -1)
        cw = build_cw(qw, jnp.float32)
        acc = jnp.dot(tv, cw)
    elif t.scale.shape[1] == 1:  # per_row: single int GEMM then row scale
        tv = t.values.reshape(m, -1)
        cw = build_cw(qw, jnp.int8)
        acc = jax.lax.dot_general(
            tv, cw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32) * t.scale[:, 0, 0][:, None]
    else:  # per_group: dequantize table entries, f32 GEMM
        tv = (t.values.astype(jnp.float32) * t.scale).reshape(m, -1)
        cw = build_cw(qw, jnp.float32)
        acc = jnp.dot(tv, cw)
    out = acc * qw.scale[None, :]
    out = zero_point_correction(out, qw, t.rowsum)
    return out.astype(out_dtype)
