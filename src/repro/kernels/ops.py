"""Jit'd wrappers around the Pallas kernels.

Handle padding to block multiples, table precompute (fused or supplied),
per-row scale closed-form computation, zero-point correction (rank-1 update
outside the kernel), and block-shape selection via the LMMA tile scheduler.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import table as table_mod
from repro.core.lmma import LMMADescriptor, schedule_tiles
from repro.core.quantize import QuantizedWeight
from repro.core.table import Table
from repro.kernels import ref
from repro.kernels.dequant_mpgemm import dequant_mpgemm_pallas
from repro.kernels.lut_mpgemm import lut_mpgemm_pallas
from repro.kernels.table_precompute import table_precompute_pallas

__all__ = ["table_precompute", "lut_mpgemm", "dequant_mpgemm", "pick_blocks"]


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pick_blocks(m, n, g, k_group, planes, max_bm=256, max_bn=512, max_bg=512):
    """Block shapes: scheduler-elongated but clamped to (padded) problem."""
    desc = LMMADescriptor(m=m, n=n, k=g * k_group, w_bits=planes, k_group=k_group)
    ts = schedule_tiles(desc)
    bm = min(ts.bm, max_bm)
    bn = min(ts.bn, max_bn)
    bg = min(ts.bg, max_bg)
    # keep K-blocks byte-aligned for the packed stream
    while (bg * planes * k_group) % 8:
        bg *= 2
    return bm, bn, bg


def table_precompute(a: jax.Array, k_group: int = 4,
                     table_quant: Optional[str] = "per_row",
                     *, block_m: int = 64, block_g: Optional[int] = None,
                     interpret: bool = False) -> Table:
    """Pallas-backed independent precompute operator (§3.1.1)."""
    m, k_total = a.shape
    g = k_total // k_group
    block_m = min(block_m, m) if m % min(block_m, m) == 0 else block_m
    ap = _pad_to(_pad_to(a, block_m, 0), 1, 1)
    mp = ap.shape[0]
    if block_g is None:
        block_g = min(128, g)
    gpad = (-g) % block_g
    if gpad:
        ap = jnp.pad(ap, ((0, 0), (0, gpad * k_group)))
    rowsum = jnp.sum(a.astype(jnp.float32), axis=-1)
    row_scale = None
    if table_quant == "per_row":
        am = table_mod.group_absmax(a.astype(jnp.float32).reshape(m, g, k_group))
        row_scale = (jnp.maximum(jnp.max(am, axis=-1), 1e-30) / 127.0)[:, None]
        row_scale = _pad_to(row_scale, block_m, 0)
        row_scale = jnp.where(row_scale == 0, 1.0, row_scale)
    values, scale = table_precompute_pallas(
        ap, k_group, table_quant, row_scale,
        block_m=block_m, block_g=block_g, interpret=interpret)
    e = 1 << (k_group - 1)
    values = values[:m, : g * e].reshape(m, g, e)
    if table_quant is None:
        return Table(values, None, rowsum, k_group)
    if table_quant == "per_row":
        return Table(values, row_scale[:m].reshape(m, 1, 1), rowsum, k_group)
    return Table(values, scale[:m, :g].reshape(m, g, 1), rowsum, k_group)


def lut_mpgemm(x: jax.Array, qw: QuantizedWeight, *,
               table_quant: Optional[str] = "per_row",
               table: Optional[Table] = None,
               block_m: Optional[int] = None, block_n: Optional[int] = None,
               block_g: Optional[int] = None,
               interpret: bool = False) -> jax.Array:
    """LUT mpGEMM via the Pallas kernel (table fused or precomputed)."""
    m = x.shape[0]
    g, e = qw.g, 1 << (qw.k_group - 1)
    planes = qw.num_planes
    bm, bn, bg = pick_blocks(m, qw.n, g, qw.k_group, planes)
    bm = block_m or min(bm, max(8, m))
    bn = block_n or min(bn, qw.n)
    bg = block_g or min(bg, g)
    if table is None:
        table = table_precompute(x, qw.k_group, table_quant,
                                 block_m=min(64, bm), interpret=interpret)
    tv = table.values.reshape(m, g * e)
    ts = None if table.scale is None else table.scale.reshape(m, -1)

    # pad to block multiples
    tvp = _pad_to(_pad_to(tv, bm, 0), bg * e, 1)
    mp = tvp.shape[0]
    gp = tvp.shape[1] // e
    tsp = None
    if ts is not None:
        tsp = _pad_to(ts, bm, 0)
        if ts.shape[1] != 1:  # per_group
            tsp = _pad_to(tsp, bg, 1)
        tsp = jnp.where(tsp == 0, 1.0, tsp)
    pkp = qw.packed
    pb_full = gp * planes * qw.k_group // 8
    if pkp.shape[1] < pb_full:
        pkp = jnp.pad(pkp, ((0, 0), (0, pb_full - pkp.shape[1])))
    # NOTE: padded K-groups contribute sign=+? fields decoded from zero bytes:
    # field 0 -> sign 0, idx 0 -> CW += Σ_b ps_b * onehot(0) ≠ 0 at entry 0.
    # But the padded *table values* are 0 (A padded with zeros), so padded
    # groups contribute 0 regardless of CW. Padding along N handled below.
    pkp = _pad_to(pkp, bn, 0)
    wsp = _pad_to(qw.scale.astype(jnp.float32), bn, 0)
    np_ = pkp.shape[0]

    out = lut_mpgemm_pallas(
        tvp, tsp, pkp, wsp, k_group=qw.k_group, planes=planes,
        plane_scales=qw.plane_scales,
        n=np_, block_m=bm, block_n=bn, block_g=bg, interpret=interpret)
    out = out[:m, :qw.n]
    return ref.zero_point_correction(out, qw, table.rowsum)


def dequant_mpgemm(x: jax.Array, qw: QuantizedWeight, *,
                   block_m: int = 64, block_n: int = 256, block_g: int = 64,
                   interpret: bool = False) -> jax.Array:
    m = x.shape[0]
    g = qw.g
    planes = qw.num_planes
    bm = min(block_m, max(8, m))
    bn = min(block_n, qw.n)
    bg = min(block_g, g)
    while (bg * planes * qw.k_group) % 8:
        bg *= 2
    xp = _pad_to(_pad_to(x, bm, 0), bg * qw.k_group, 1)
    mp, kp = xp.shape
    gp = kp // qw.k_group
    pkp = qw.packed
    pb_full = gp * planes * qw.k_group // 8
    if pkp.shape[1] < pb_full:
        pkp = jnp.pad(pkp, ((0, 0), (0, pb_full - pkp.shape[1])))
    pkp = _pad_to(pkp, bn, 0)
    wsp = _pad_to(qw.scale.astype(jnp.float32), bn, 0)
    out = dequant_mpgemm_pallas(
        xp, pkp, wsp, k_group=qw.k_group, planes=planes,
        plane_scales=qw.plane_scales,
        n=pkp.shape[0], block_m=bm, block_n=bn, block_g=bg,
        interpret=interpret)[:m, :qw.n]
    if qw.zero_prime is not None:
        rowsum = jnp.sum(x.astype(jnp.float32), axis=-1)
        out = ref.zero_point_correction(out, qw, rowsum)
    return out
