"""Jit'd wrappers around the Pallas kernels.

Handle padding to block multiples, table precompute (fused or supplied),
per-row scale closed-form computation, zero-point correction (rank-1 update
outside the kernel), and block-shape selection via the LMMA tile scheduler.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import table as table_mod
from repro.obs import dispatch as dispatch_obs
from repro.core.lmma import (LMMADescriptor, TileSchedule, schedule_tiles,
                             select_fusion)
from repro.core.quantize import QuantizedWeight
from repro.core.table import Table
from repro.kernels import ref
from repro.kernels.dequant_mpgemm import dequant_mpgemm_pallas
from repro.kernels.fused_lut_mpgemm import fused_lut_mpgemm_pallas
from repro.kernels.lut_mpgemm import lut_mpgemm_pallas
from repro.kernels.table_precompute import table_precompute_pallas

from repro.core.mpgemm import FUSION_MODES

__all__ = ["table_precompute", "lut_mpgemm", "fused_lut_mpgemm",
           "dequant_mpgemm", "pick_blocks", "auto_fusion", "resolve_dispatch",
           "plan_local_shape", "FUSION_MODES"]


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pick_blocks(m, n, g, k_group, planes, max_bm=256, max_bn=512, max_bg=512):
    """Block shapes: scheduler-elongated but clamped to (padded) problem."""
    desc = LMMADescriptor(m=m, n=n, k=g * k_group, w_bits=planes, k_group=k_group)
    ts = schedule_tiles(desc)
    bm = min(ts.bm, max_bm)
    bn = min(ts.bn, max_bn)
    bg = min(ts.bg, max_bg)
    # keep K-blocks byte-aligned for the packed stream
    while (bg * planes * k_group) % 8:
        bg *= 2
    return bm, bn, bg


def _closed_form_row_scale(a: jax.Array, g: int, k_group: int) -> jax.Array:
    """[M, 1] per-row INT8 table scale from A alone (table.group_absmax).

    Shared by the staged precompute wrapper and the fused kernel wrapper so
    both paths quantize with the bit-identical scale.
    """
    m = a.shape[0]
    am = table_mod.group_absmax(a.astype(jnp.float32).reshape(m, g, k_group))
    return (jnp.maximum(jnp.max(am, axis=-1), 1e-30) / 127.0)[:, None]


def _clamp_blocks(m, n, g, k_group, planes, block_m, block_n, block_g):
    """Block shapes clamped to the (padded) problem, byte-realigned.

    Clamping bg to a small/odd g can undo the alignment pick_blocks
    established, so the packed-stream byte alignment is re-applied after
    every clamp. Shared by every mpGEMM wrapper.
    """
    if block_m is None or block_n is None or block_g is None:
        bm, bn, bg = pick_blocks(m, n, g, k_group, planes)
    else:
        bm = bn = bg = None  # all supplied; skip the scheduler search
    bm = block_m or min(bm, max(8, m))
    bn = block_n or min(bn, n)
    bg = block_g or min(bg, g)
    while (bg * planes * k_group) % 8:
        bg *= 2
    return bm, bn, bg


def auto_fusion(m, n, g, k_group, planes,
                block_m=None, block_n=None, block_g=None) -> str:
    """Resolve ``fusion="auto"`` for one mpGEMM shape: clamp blocks exactly
    the way the wrappers do, then ask the LMMA scheduler whether the fused
    working set fits VMEM. The single source of truth for the auto decision
    — models.layers.resolve_fusion delegates here.
    """
    bm, bn, bg = _clamp_blocks(m, n, g, k_group, planes,
                               block_m, block_n, block_g)
    desc = LMMADescriptor(m=m, n=n, k=g * k_group, w_bits=planes,
                          k_group=k_group)
    return select_fusion(desc, TileSchedule(bm, bn, bg, 0, 0, 0, 0))


def plan_local_shape(m, n):
    """Per-shard (m, n) under the active AxisPlan (trace-time).

    Under tensor-parallel decode the arrays reaching a wrapper are GLOBAL
    (pjit partitions them later), but each device only computes its
    [m/dp, n/mp] tile of a column-parallel projection — block shapes and
    tuned-cache keys must describe that local tile, or the tuner measures
    (and the dispatcher blocks for) work mp·dp times the size any single
    device ever runs. Dims that do not divide stay global, matching the
    replicate fallback in distributed.sharding.resolve_physical_spec.
    """
    from repro.distributed.sharding import current_plan
    plan = current_plan()
    if plan is None:
        return m, n
    dp = plan.axis_size("batch")
    mp = plan.axis_size("model")
    if dp > 1 and m % dp == 0:
        m //= dp
    if mp > 1 and n % mp == 0:
        n //= mp
    return m, n


def resolve_dispatch(m, n, g, k_group, planes, *, fusion="auto",
                     block_m=None, block_n=None, block_g=None,
                     table_quant: Optional[str] = "per_row"):
    """Trace-time dispatch decision for one mpGEMM shape.

    Returns the fully-resolved ``(fusion, bm, bn, bg)`` the wrappers will
    run — the single source of truth shared by ``lut_mpgemm`` and the
    round-trip tests. Under an active AxisPlan the decision is made on the
    PER-SHARD local tile (``plan_local_shape``), and the tuned-cache key is
    the local shape — what each device actually executes. Policies:

      * ``"tuned"``  — consult the active autotune cache (core.autotune);
        a hit supplies the measured fusion and fills any block knob the
        caller left unset (caller-pinned blocks always win); a miss — no
        active cache, shape never tuned, or the entry failed sanitation —
        degrades to ``"auto"``.
      * ``"auto"``   — clamp blocks, then the LMMA VMEM-fit heuristic.
      * ``"fused"``/``"staged"`` — forced, blocks clamped as usual.
    """
    m, n = plan_local_shape(m, n)
    requested = fusion
    source = "forced"
    if fusion == "tuned":
        tc = autotune.lookup_tuned(m, n, g, k_group, planes,
                                   table_quant=table_quant)
        if tc is not None:
            source = "tuned"
            fusion = tc.fusion
            block_m = block_m or tc.block_m
            block_n = block_n or tc.block_n
            block_g = block_g or tc.block_g
        else:
            fusion = "auto"
    bm, bn, bg = _clamp_blocks(m, n, g, k_group, planes,
                               block_m, block_n, block_g)
    if fusion == "auto":
        source = "heuristic"
        fusion = auto_fusion(m, n, g, k_group, planes, bm, bn, bg)
    # trace-time dispatch profiling (obs.dispatch): a no-op unless a
    # recorder is active — a serve run can dump exactly which kernel
    # configs its compiled programs contain
    dispatch_obs.record(
        "dispatch",
        autotune.shape_key(m, n, g, k_group, planes,
                           table_quant=table_quant),
        fusion, requested, source, (bm, bn, bg))
    return fusion, bm, bn, bg


def _check_not_plane_sliced(qw: QuantizedWeight, opname: str):
    """The Pallas kernels unpack the byte stream in-kernel with
    ``num_planes`` as the per-group field stride — a plane-sliced draft view
    (stored_planes != num_planes) would decode the wrong bytes. Sliced views
    run through lut_xla / dequant modes, which go via ``sign_idx()``."""
    if getattr(qw, "is_plane_sliced", False):
        raise NotImplementedError(
            f"{opname}: plane-sliced QuantizedWeight views (planes "
            f"[{qw.plane_start}:{qw.plane_start + qw.num_planes}] of "
            f"{qw.stored_planes} stored) are not supported by the Pallas "
            f"kernels; use mode='lut_xla' or 'dequant' for the draft view")


def _padded_row_scale(a: jax.Array, g: int, k_group: int, bm: int):
    rs = _pad_to(_closed_form_row_scale(a, g, k_group), bm, 0)
    return jnp.where(rs == 0, 1.0, rs)  # padded rows get an inert scale


def _pad_packed(qw: QuantizedWeight, gp: int, bn: int):
    """Pad packed codes to gp K-groups / bn N-rows; pad wscale alongside.

    NOTE: padded K-groups decode from zero bytes to sign=0, idx=0 fields, so
    CW is nonzero at entry 0 — but the corresponding *table values* are 0
    (A is zero-padded), so padded groups contribute 0 regardless of CW.
    """
    pkp = qw.packed
    pb_full = gp * qw.num_planes * qw.k_group // 8
    if pkp.shape[1] < pb_full:
        pkp = jnp.pad(pkp, ((0, 0), (0, pb_full - pkp.shape[1])))
    pkp = _pad_to(pkp, bn, 0)
    wsp = _pad_to(qw.scale.astype(jnp.float32), bn, 0)
    return pkp, wsp


def table_precompute(a: jax.Array, k_group: int = 4,
                     table_quant: Optional[str] = "per_row",
                     *, block_m: int = 64, block_g: Optional[int] = None,
                     interpret: bool = False) -> Table:
    """Pallas-backed independent precompute operator (§3.1.1)."""
    m, k_total = a.shape
    g = k_total // k_group
    block_m = min(block_m, m) if m % min(block_m, m) == 0 else block_m
    ap = _pad_to(_pad_to(a, block_m, 0), 1, 1)
    mp = ap.shape[0]
    if block_g is None:
        block_g = min(128, g)
    gpad = (-g) % block_g
    if gpad:
        ap = jnp.pad(ap, ((0, 0), (0, gpad * k_group)))
    rowsum = jnp.sum(a.astype(jnp.float32), axis=-1)
    row_scale = None
    if table_quant == "per_row":
        row_scale = _padded_row_scale(a, g, k_group, block_m)
    values, scale = table_precompute_pallas(
        ap, k_group, table_quant, row_scale,
        block_m=block_m, block_g=block_g, interpret=interpret)
    e = 1 << (k_group - 1)
    values = values[:m, : g * e].reshape(m, g, e)
    if table_quant is None:
        return Table(values, None, rowsum, k_group)
    if table_quant == "per_row":
        return Table(values, row_scale[:m].reshape(m, 1, 1), rowsum, k_group)
    return Table(values, scale[:m, :g].reshape(m, g, 1), rowsum, k_group)


def fused_lut_mpgemm(x: jax.Array, qw: QuantizedWeight, *,
                     table_quant: Optional[str] = "per_row",
                     block_m: Optional[int] = None,
                     block_n: Optional[int] = None,
                     block_g: Optional[int] = None,
                     interpret: bool = False) -> jax.Array:
    """Single-kernel precompute→lookup mpGEMM: the table never leaves VMEM.

    Streams activation blocks, rebuilds each [bm, bg·E] table block on the
    MXU in-VMEM (quantizing in-register for per_row/per_group), and contracts
    immediately against CW — the fused DFG of §3.1.1. Bit-exact with the
    staged ``table_precompute`` + ``lut_mpgemm`` composition on the per_row
    int8 path, float-tolerance-equal otherwise.
    """
    _check_not_plane_sliced(qw, "fused_lut_mpgemm")
    m = x.shape[0]
    g = qw.g
    planes = qw.num_planes
    bm, bn, bg = _clamp_blocks(m, qw.n, g, qw.k_group, planes,
                               block_m, block_n, block_g)

    rowsum = jnp.sum(x.astype(jnp.float32), axis=-1)
    row_scale = None
    if table_quant == "per_row":
        row_scale = _padded_row_scale(x, g, qw.k_group, bm)

    # pad activations to (bm, bg·K) blocks; zero rows/groups produce zero
    # table entries, so padded blocks contribute nothing to the output
    xp = _pad_to(_pad_to(x, bm, 0), bg * qw.k_group, 1)
    gp = xp.shape[1] // qw.k_group
    pkp, wsp = _pad_packed(qw, gp, bn)

    out = fused_lut_mpgemm_pallas(
        xp, row_scale, pkp, wsp, k_group=qw.k_group,
        table_quant=table_quant, planes=planes,
        plane_scales=qw.plane_scales, n=pkp.shape[0],
        block_m=bm, block_n=bn, block_g=bg, interpret=interpret)
    out = out[:m, :qw.n]
    return ref.zero_point_correction(out, qw, rowsum)


def lut_mpgemm(x: jax.Array, qw: QuantizedWeight, *,
               table_quant: Optional[str] = "per_row",
               table: Optional[Table] = None,
               fusion: str = "auto",
               block_m: Optional[int] = None, block_n: Optional[int] = None,
               block_g: Optional[int] = None,
               interpret: bool = False) -> jax.Array:
    """LUT mpGEMM via the Pallas kernels.

    ``fusion`` selects the pipeline: "fused" runs the single-kernel
    precompute→lookup datapath (table stays in VMEM); "staged" runs
    ``table_precompute_pallas`` then ``lut_mpgemm_pallas`` with the table
    round-tripping through HBM; "auto" defers to the LMMA scheduler
    (``core.lmma.select_fusion``), which picks fused whenever the fused
    working set fits the VMEM budget; "tuned" consults the persistent
    measured-time autotune cache (``core.autotune``) and falls back to
    "auto" on a miss. A caller-supplied ``table=`` (the cross-consumer
    amortization of §3.1.1) always implies staged — the table already
    exists.
    """
    if fusion not in FUSION_MODES:
        raise ValueError(f"fusion {fusion!r} not in {FUSION_MODES}")
    _check_not_plane_sliced(qw, "lut_mpgemm")
    m = x.shape[0]
    g, e = qw.g, 1 << (qw.k_group - 1)
    planes = qw.num_planes
    fusion, bm, bn, bg = resolve_dispatch(
        m, qw.n, g, qw.k_group, planes, fusion=fusion, block_m=block_m,
        block_n=block_n, block_g=block_g, table_quant=table_quant)
    if table is None and fusion == "fused":
        return fused_lut_mpgemm(
            x, qw, table_quant=table_quant, block_m=bm, block_n=bn,
            block_g=bg, interpret=interpret)
    if table is None:
        table = table_precompute(x, qw.k_group, table_quant,
                                 block_m=min(64, bm), interpret=interpret)
    tv = table.values.reshape(m, g * e)
    ts = None if table.scale is None else table.scale.reshape(m, -1)

    # pad to block multiples
    tvp = _pad_to(_pad_to(tv, bm, 0), bg * e, 1)
    mp = tvp.shape[0]
    gp = tvp.shape[1] // e
    tsp = None
    if ts is not None:
        tsp = _pad_to(ts, bm, 0)
        if ts.shape[1] != 1:  # per_group
            tsp = _pad_to(tsp, bg, 1)
        tsp = jnp.where(tsp == 0, 1.0, tsp)
    pkp, wsp = _pad_packed(qw, gp, bn)
    np_ = pkp.shape[0]

    out = lut_mpgemm_pallas(
        tvp, tsp, pkp, wsp, k_group=qw.k_group, planes=planes,
        plane_scales=qw.plane_scales,
        n=np_, block_m=bm, block_n=bn, block_g=bg, interpret=interpret)
    out = out[:m, :qw.n]
    return ref.zero_point_correction(out, qw, table.rowsum)


def dequant_mpgemm(x: jax.Array, qw: QuantizedWeight, *,
                   block_m: int = 64, block_n: int = 256, block_g: int = 64,
                   interpret: bool = False) -> jax.Array:
    _check_not_plane_sliced(qw, "dequant_mpgemm")
    m = x.shape[0]
    g = qw.g
    planes = qw.num_planes
    bm, bn, bg = _clamp_blocks(m, qw.n, g, qw.k_group, planes,
                               min(block_m, max(8, m)), min(block_n, qw.n),
                               min(block_g, g))
    xp = _pad_to(_pad_to(x, bm, 0), bg * qw.k_group, 1)
    mp, kp = xp.shape
    gp = kp // qw.k_group
    pkp, wsp = _pad_packed(qw, gp, bn)
    out = dequant_mpgemm_pallas(
        xp, pkp, wsp, k_group=qw.k_group, planes=planes,
        plane_scales=qw.plane_scales,
        n=pkp.shape[0], block_m=bm, block_n=bn, block_g=bg,
        interpret=interpret)[:m, :qw.n]
    if qw.zero_prime is not None:
        rowsum = jnp.sum(x.astype(jnp.float32), axis=-1)
        out = ref.zero_point_correction(out, qw, rowsum)
    return out
