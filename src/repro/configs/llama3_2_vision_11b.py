"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. Vision frontend stubbed."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256, rope_theta=5e5,
    xattn_every=5, n_image_tokens=1601,
    quant=LUT_W2, source="hf:meta-llama/Llama-3.2-11B-Vision")


def reduced():
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=0, d_ff=192, vocab_size=512,
                          xattn_every=2, n_image_tokens=16)
