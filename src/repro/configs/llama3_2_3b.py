"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, rope_theta=5e5,
    quant=LUT_W2, source="hf:meta-llama/Llama-3.2-3B")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                          head_dim=0, d_ff=256, vocab_size=512)
