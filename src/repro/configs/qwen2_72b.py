"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2
import jax.numpy as jnp

CONFIG = ArchConfig(
    arch_id="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    param_dtype=jnp.bfloat16,
    quant=LUT_W2, source="arXiv:2407.10671")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=0, d_ff=256, vocab_size=512,
                          param_dtype=jnp.float32)
