"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab_size=151936, qkv_bias=True,
    quant=LUT_W2, source="hf:Qwen/Qwen1.5-0.5B")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=0, d_ff=160, vocab_size=512)
