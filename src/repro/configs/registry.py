"""Architecture configs + shape registry.

Each assigned architecture gets a module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (exact published numbers) — registered here.  Every
config also provides a ``reduced()`` smoke variant (same family, tiny dims)
for CPU tests, and the four assigned input shapes with per-family skip rules
(see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    window: Optional[int] = None   # sliding-window size for long decode
    skip: Optional[str] = None     # reason if this (arch, shape) is skipped


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    norm_eps: float = 1e-5
    # ssm (mamba1/2)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    d_inner: int = 0
    dt_rank: int = 0
    ssm_heads: int = 0
    ssm_chunk: int = 64
    # hybrid
    attn_every: int = 6
    # moe
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    dense_d_ff: int = 0
    # vlm
    xattn_every: int = 0
    n_image_tokens: int = 0
    # audio (enc-dec): n_layers = decoder layers
    enc_layers: int = 0
    n_audio_frames: int = 0
    max_positions: int = 0
    # numerics / execution
    kv_cache_dtype: str = "bf16"   # "bf16" | "int8" (quantized KV, paper §5)
    param_dtype: Any = jnp.float32
    activation_dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    # the paper's technique: quant config dict or None
    #   {"qat": bool, "weight_bits", "scheme", "mpgemm_mode", "table_quant",
    #    "k_group", "fusion"}  — fusion ∈ {"auto","fused","staged","tuned"} picks the
    #   lut_pallas precompute placement (fused = table built in-VMEM, §3.1.1)
    quant: Optional[dict] = None
    notes: str = ""
    source: str = ""

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("ssm", "hybrid") and not self.d_inner:
            object.__setattr__(self, "d_inner", self.expand * self.d_model)
        if self.family == "ssm" and not self.dt_rank:
            object.__setattr__(self, "dt_rank", math.ceil(self.d_model / 16))
        if self.family == "hybrid" and not self.ssm_heads:
            object.__setattr__(self, "ssm_heads", max(1, self.d_inner // 64))

    # -- derived -------------------------------------------------------------
    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def with_quant(self, **kw) -> "ArchConfig":
        q = dict(self.quant or {})
        q.update(kw)
        return self.replace(quant=q)

    def module(self):
        from repro.models import api
        return api.get_module(self.family)

    def shapes(self) -> List[ShapeSpec]:
        sub_quadratic = self.family in ("ssm", "hybrid")
        long_skip = (None if sub_quadratic else
                     "full-attention arch: 500k decode needs sub-quadratic "
                     "attention (DESIGN.md §5)")
        return [
            ShapeSpec("train_4k", 4096, 256, "train"),
            ShapeSpec("prefill_32k", 32768, 32, "prefill"),
            ShapeSpec("decode_32k", 32768, 128, "decode"),
            ShapeSpec("long_500k", 524288, 1, "decode",
                      window=(8192 if self.family == "hybrid" else None),
                      skip=long_skip),
        ]

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes():
            if s.name == name:
                return s
        raise KeyError(name)

    def num_params(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, v, l = self.d_model, self.vocab_size, self.n_layers
        n = 2 * v * d  # embed + head
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp3 = 3 * d * self.d_ff
        mlp2 = 2 * d * self.d_ff
        if self.family == "dense":
            n += l * (attn + mlp3)
        elif self.family == "moe":
            nd_ = self.first_dense_layers
            n += nd_ * (attn + 3 * d * (self.dense_d_ff or self.d_ff))
            per = attn + self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            per += self.n_shared_experts * 3 * d * self.d_ff
            n += (l - nd_) * per
        elif self.family == "ssm":
            di, ds = self.d_inner, self.ssm_state
            per = d * 2 * di + di * (self.dt_rank + 2 * ds) + self.dt_rank * di
            per += di * ds + 2 * di + di * d
            n += l * per
        elif self.family == "hybrid":
            di, ds = self.d_inner, self.ssm_state
            per = d * (2 * di + 2 * ds + self.ssm_heads) + di * d + 2 * di
            n += l * per
            n += attn + mlp3  # one shared block
        elif self.family == "vlm":
            ng = l // self.xattn_every
            n += (l - ng) * (attn + mlp3) + ng * (attn + mlp3)
        elif self.family == "audio":
            n += self.enc_layers * (attn + mlp2) + l * (2 * attn + mlp2)
            n += self.max_positions * d
        return n

    def active_params(self) -> int:
        """Activated params per token (MoE: top-k + shared experts only)."""
        if self.family != "moe":
            return self.num_params()
        d, l = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        nd_ = self.first_dense_layers
        n = 2 * self.vocab_size * d
        n += nd_ * (attn + 3 * d * (self.dense_d_ff or self.d_ff))
        per = attn + (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        n += (l - nd_) * per
        return n


_REGISTRY: Dict[str, str] = {
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "llama-3.2-vision-11b": "repro.configs.llama3_2_vision_11b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    # the paper's own end-to-end model (Table 1)
    "paper-bitnet-3b": "repro.configs.paper_bitnet_3b",
}

ASSIGNED = [k for k in _REGISTRY if k != "paper-bitnet-3b"]


def _module_for(arch_id: str):
    try:
        modname = _REGISTRY[arch_id]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch_id!r}; known: {', '.join(_REGISTRY)}"
        ) from None
    return importlib.import_module(modname)


def get_config(arch_id: str) -> ArchConfig:
    return _module_for(arch_id).CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    return _module_for(arch_id).reduced()


def list_archs() -> List[str]:
    return list(_REGISTRY)
