"""paper-bitnet-3b: BitNet b1.58 3B (paper Table 1 / §4.4 eval model) —
ternary weights, INT8-path activations, llama-ish 3B geometry
[arXiv:2402.17764]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import TERNARY

CONFIG = ArchConfig(
    arch_id="paper-bitnet-3b", family="dense",
    n_layers=26, d_model=3200, n_heads=32, n_kv_heads=32, d_ff=8640,
    vocab_size=32000,
    quant=TERNARY, source="arXiv:2402.17764 (BitNet b1.58)")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=0, d_ff=192, vocab_size=512)
