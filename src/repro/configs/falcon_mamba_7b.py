"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free, vocab 65024,
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, d_ff=0, vocab_size=65024,
    ssm_state=16, d_conv=4, expand=2,  # d_inner 8192, dt_rank 256
    ssm_chunk=16,
    quant=LUT_W2, source="arXiv:2410.05355",
    notes="attention-free; long_500k runs (O(1) decode state)")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, vocab_size=256,
                          ssm_state=4, ssm_chunk=4)
