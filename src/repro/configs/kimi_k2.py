"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert)
vocab=163840, MoE 384e top-8, 1 shared expert, first layer dense —
trillion-param MoE [arXiv:2501.kimi2; unverified]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2
import jax.numpy as jnp

CONFIG = ArchConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, num_experts=384, top_k=8, n_shared_experts=1,
    first_dense_layers=1, dense_d_ff=18432, rope_theta=5e4,
    capacity_factor=1.0,
    param_dtype=jnp.bfloat16,  # 1T params: bf16 + Adafactor to fit HBM
    quant=LUT_W2, source="arXiv:2501 (Kimi K2 tech report)")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=0, d_ff=64, vocab_size=512, num_experts=8,
                          top_k=2, capacity_factor=8.0, dense_d_ff=128, first_dense_layers=1,
                          param_dtype=jnp.float32)
