"""Shared quant defaults: the paper's technique as configured per arch.

``LUT_W2`` is the paper-faithful serve config (2-bit symmetric weights on the
odd grid, K=4 groups, XLA LUT path). ``table_quant="auto"`` resolves per
backend (``core.mpgemm.resolve_table_quant``): the paper's INT8 per-row
tables where an int8 GEMM fast path exists (TPU MXU / the LUT unit's int8
datapath), float tables on CPU emulation where quantizing the table costs
both ops and accuracy. Pin ``"per_row"`` to force the paper format. Training
steps add ``qat=True`` (STE fake-quant forward, paper §5).
"""

LUT_W2 = {
    "weight_bits": 2,
    "scheme": "symmetric",
    "mpgemm_mode": "lut_xla",
    "table_quant": "auto",
    "k_group": 4,
}

LUT_W4 = dict(LUT_W2, weight_bits=4)
LUT_W1 = dict(LUT_W2, weight_bits=1)
TERNARY = dict(LUT_W2, scheme="ternary")  # BitNet b1.58
DEQUANT_W2 = dict(LUT_W2, mpgemm_mode="dequant")  # paper's baseline
