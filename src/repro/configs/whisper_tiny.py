"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv frontend stubbed [arXiv:2212.04356; unverified]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865, enc_layers=4, n_audio_frames=1500,
    max_positions=32768,  # sized for decode_32k (>> whisper's native 448)
    quant=LUT_W2, source="arXiv:2212.04356",
    notes="frontend stub: input_specs() provides precomputed frame embeddings")


def reduced():
    return CONFIG.replace(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, head_dim=0, d_ff=128, vocab_size=512,
                          n_audio_frames=24, max_positions=128)
