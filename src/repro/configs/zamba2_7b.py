"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000, ssm_state=64, d_conv=4, expand=2,  # d_inner 7168
    ssm_heads=112, ssm_chunk=64, attn_every=6,
    # mamba2 in/out projections stay fp: error injected into the SSM
    # recurrence compounds over sequence AND over the reused shared blocks
    quant=dict(LUT_W2, skip="ssm/(in_proj|out_proj)"),
    source="arXiv:2411.15242",
    notes="long_500k uses an 8k sliding-window KV for the shared attn "
          "(DESIGN.md §5); mamba2 state is O(1)")


def reduced():
    return CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=0, d_ff=192, vocab_size=512, ssm_state=8,
                          ssm_heads=4, ssm_chunk=4, attn_every=2)
