"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024(expert)
vocab=50304, MoE 64e top-8 [arXiv:2409.02060; hf]."""
from repro.configs.registry import ArchConfig
from repro.configs._defaults import LUT_W2

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab_size=50304, num_experts=64, top_k=8,
    # attention stays fp: routing decisions sit downstream of attn outputs
    # and quantization jitter there flips top-k picks (experts carry ~95% of
    # the params, so the packed-weight win is preserved)
    quant=dict(LUT_W2, skip="attn"), source="arXiv:2409.02060")


def reduced():
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=0, d_ff=64, vocab_size=512, num_experts=8,
                          top_k=2, capacity_factor=8.0)
