"""Elastic re-meshing: shrink the data axis after host loss, rescale the
batch schedule, and reshard the checkpointed state onto the new mesh.

Elasticity model (data-parallel elasticity, the standard large-fleet
policy): the model axes (model/TP, expert/EP, pp) are *rigid* — losing a TP
shard makes the program non-runnable — so failures are absorbed by the
replicated axis: data. Given F failed hosts we drop whole data-rows of the
mesh, keep the global batch constant by raising microbatch accumulation, and
resume from the latest checkpoint (params are data-replicated, so no state
is lost).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import AxisPlan


@dataclasses.dataclass(frozen=True)
class ElasticDecision:
    old_data: int
    new_data: int
    microbatch_scale: int          # multiply grad-accum steps by this
    dropped_rows: Tuple[int, ...]  # data-axis indices removed


def plan_downsize(mesh_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
                  failed_flat_indices: List[int]) -> ElasticDecision:
    """Choose the largest feasible data-axis size after failures.

    Failures anywhere in a data-row kill that row (its TP/EP shards are
    incomplete). The new data size is the count of intact rows, rounded down
    to a power of two so batch rescaling stays integral.
    """
    shape = tuple(mesh_shape)
    data_ax = axis_names.index("data")
    grid = np.arange(int(np.prod(shape))).reshape(shape)
    rows_axis = tuple(i for i in range(len(shape)) if i != data_ax)
    failed = set(failed_flat_indices)
    intact = []
    dropped = []
    for r in range(shape[data_ax]):
        row = np.take(grid, r, axis=data_ax).ravel()
        (dropped if any(int(d) in failed for d in row) else intact).append(r)
    new_data = 1 << int(math.floor(math.log2(max(1, len(intact)))))
    scale = shape[data_ax] // new_data
    return ElasticDecision(shape[data_ax], new_data, scale, tuple(dropped))


def remesh(plan: AxisPlan, decision: ElasticDecision) -> AxisPlan:
    """Build the shrunken mesh from surviving devices (same axis names)."""
    mesh = plan.mesh
    names = mesh.axis_names
    data_ax = names.index("data")
    devs = mesh.devices
    keep = [r for r in range(devs.shape[data_ax])
            if r not in decision.dropped_rows][: decision.new_data]
    new_devs = np.take(devs, keep, axis=data_ax)
    new_mesh = Mesh(new_devs, names)
    return dataclasses.replace(plan, mesh=new_mesh)


def reshard_state(state, shardings_fn, new_plan: AxisPlan):
    """Reshard a (restored) train state onto the new mesh."""
    sh = shardings_fn(state, new_plan)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x, state, sh)
