"""jax version compatibility for the distributed layer.

The distributed code targets the current jax API (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``); older jax (0.4.x, the pinned CI
toolchain) exposes the same functionality under
``jax.experimental.shard_map.shard_map(..., check_rep=...)`` and has no
axis-type concept. This module is the single place that bridges the gap —
the same pattern as ``kernels/_compat.py`` for Pallas CompilerParams.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "HAS_AXIS_TYPE"]

try:  # jax >= 0.5: AxisType exists and make_mesh takes axis_types
    from jax.sharding import AxisType  # noqa: F401
    HAS_AXIS_TYPE = True
except ImportError:
    AxisType = None
    HAS_AXIS_TYPE = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on current jax; the experimental one on 0.4.x.

    ``check_vma`` maps onto the old API's ``check_rep`` — both toggle the
    replication/varying-manual-axes check that rejects collectives whose
    replication the tracer cannot prove (our pipeline/flash-decode bodies
    legitimately mix per-shard and replicated values, so callers pass
    False).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
