"""Int8 gradient compression with error feedback.

Cross-pod gradient all-reduce is the dominant multi-pod collective for
data-parallel training. Quantizing gradients to INT8 (blockwise absmax — the
same primitive as the paper's table quantization) cuts that traffic 4× vs
fp32 / 2× vs bf16. The quantization error is carried in an error-feedback
buffer and re-added next step (EF-SGD style), which keeps convergence.

Under pjit the compression is applied to the *local* gradient before the
(XLA-inserted) all-reduce consumes it; the EF buffer is sharded like params.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_BLOCK = 1024


def _quantize_int8(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, _BLOCK)
    s = jnp.maximum(jnp.max(jnp.abs(blk), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blk / s), -127, 127)
    deq = (q * s).reshape(-1)[: x.size].reshape(x.shape)
    return deq


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress_tree(grads, ef: Optional = None) -> Tuple:
    """Returns (compressed grads, new error-feedback tree)."""
    if ef is None:
        ef = init_error_feedback(grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if g.size < _BLOCK:  # tiny tensors not worth compressing
            return gf, jnp.zeros_like(e)
        deq = _quantize_int8(gf)
        return deq, gf - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
