"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For a mesh with a leading "pp" axis: the layer stack [L, ...] is split into
``n_stages`` contiguous stages, each resident on one pp-shard. The schedule
is the classic GPipe loop over ``n_micro + n_stages - 1`` ticks: at every
tick each stage runs its microbatch (bubble ticks compute-but-discard) and
activations hop stage→stage+1 with jax.lax.ppermute.

This composes with the data/model axes: inside shard_map over "pp" only, the
per-stage body is still a pjit-style program over ("data", "model").
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed._compat import shard_map

__all__ = ["pipelined_forward", "split_stages"]


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked params -> [n_stages, L/n_stages, ...]."""
    def resh(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(resh, stacked_params)


def pipelined_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    staged_params,          # pytree with leading [n_stages, ...] dims
    x_micro: jax.Array,     # [n_micro, mb, ...] microbatched input
    *,
    mesh,
    n_stages: int,
    pp_axis: str = "pp",
) -> jax.Array:
    """Returns [n_micro, mb, ...] outputs of the full L-layer stack."""
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def per_stage(params_local, x_local):
        # params_local: [1, L/S, ...]; x_local: [n_micro, mb, ...]
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(pp_axis)
        mb_shape = x_local.shape[1:]
        buf = jnp.zeros((n_micro,) + mb_shape, x_local.dtype)
        carry_in = jnp.zeros(mb_shape, x_local.dtype)

        def tick(state, t):
            buf_, inflow = state
            # stage 0 feeds from the microbatch queue; others from inflow
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0,
                             x_local[mb_idx], inflow)
            y = stage_fn(params_local, x_in)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            outflow = jax.lax.ppermute(y, pp_axis, perm)
            # last stage banks its result for microbatch (t - (S-1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (t >= n_stages - 1) & (stage == n_stages - 1)
            buf_ = jax.lax.cond(
                valid,
                lambda b: jax.lax.dynamic_update_index_in_dim(b, y, out_idx, 0),
                lambda b: b, buf_)
            return (buf_, outflow), None

        (buf, _), _ = jax.lax.scan(tick, (buf, carry_in), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them so every
        # pp shard returns the same value (ppermute needs unique dests, so
        # use an all_gather + select).
        buf = jax.lax.all_gather(buf, pp_axis)[n_stages - 1]
        return buf

    spec_p = jax.tree.map(lambda _: P(pp_axis), staged_params)
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_p, P()), out_specs=P(),
        check_vma=False)
    return fn(staged_params, x_micro)
