"""Collective helpers: overlap-friendly all-gather/reduce-scatter wrappers
and the sequence-parallel boundary ops.

Sequence parallelism (SP): between blocks, activations live sharded over the
sequence dim on the data axis; attention/mpGEMM regions need the full
sequence (all-gather in) and emit partial sums (reduce-scatter out). Under
pjit these are expressed as sharding constraints — XLA inserts and schedules
the collectives (and overlaps them with compute under
--xla_tpu_enable_async_collective_*, see launch/train.py); these wrappers
centralize the constraint patterns so models stay readable.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import current_plan


def sp_scatter(x):
    """Enter an SP region: shard the sequence dim (axis 1) over data.

    When the sequence axis IS one of the batch axes (the default plan maps
    both to "data"), the batch dim stays unsharded inside the SP region — a
    mesh axis may appear at most once in a PartitionSpec, and SP spends the
    data axis on the sequence dim precisely because long-prefill batches
    are too small to fill it."""
    plan = current_plan()
    if plan is None or plan.seq is None:
        return x
    spec = [None] * x.ndim
    if plan.seq not in plan.batch:
        spec[0] = plan.resolve("batch")
    spec[1] = plan.seq
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(*spec)))


def sp_gather(x):
    """Leave an SP region: replicate the sequence dim (all-gather over seq)."""
    plan = current_plan()
    if plan is None or plan.seq is None:
        return x
    spec = [None] * x.ndim
    spec[0] = plan.resolve("batch")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(*spec)))
