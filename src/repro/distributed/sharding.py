"""Logical-axis sharding rules → NamedSharding / PartitionSpec.

The model code annotates activations with *logical* axes via :func:`shard`
(no-op outside a mesh context), and parameters are matched by path patterns
to logical specs which an :class:`AxisPlan` maps onto physical mesh axes.

Physical meshes (launch/mesh.py):
  single-pod (16, 16)      axes ("data", "model")
  multi-pod  (2, 16, 16)   axes ("pod", "data", "model")

The plan maps logical -> physical:
  batch   -> ("pod", "data")   (pod composes with data for all batch ops)
  model   -> "model"           (TP: attention heads / ffn / vocab)
  expert  -> "model"           (EP shares the TP axis by default)
  fsdp    -> "data"            (ZeRO-3 parameter sharding over data)
  seq     -> "data"            (sequence parallelism for long prefill)
  stage   -> "pp"              (pipeline axis when a 3D (pp,...) mesh is used)
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisPlan", "plan_scope", "current_plan", "shard",
           "param_spec_tree", "named_sharding_tree", "constrain_tree",
           "DEFAULT_RULES"]

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class AxisPlan:
    mesh: Mesh
    batch: Tuple[str, ...] = ("data",)
    model: Optional[str] = "model"
    expert: Optional[str] = "model"
    fsdp: Optional[str] = None          # set to "data" for ZeRO-3
    seq: Optional[str] = None           # set to "data" for sequence parallelism
    stage: Optional[str] = None         # set to "pp" for pipeline meshes

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch if len(self.batch) > 1 else self.batch[0]
        return getattr(self, logical)


@contextlib.contextmanager
def plan_scope(plan: Optional[AxisPlan]):
    prev = getattr(_state, "plan", None)
    _state.plan = plan
    try:
        yield plan
    finally:
        _state.plan = prev


def current_plan() -> Optional[AxisPlan]:
    return getattr(_state, "plan", None)


def constrain_tree(params, rules=None):
    """Apply rule-based sharding constraints to a param(-slice) tree.

    Used inside scan-over-layers bodies: without it XLA's SPMD propagation
    frequently loses the sharding of per-layer param slices inside the while
    loop, replicating both the forward all-gather result AND the backward
    grad-accumulation buffers (observed: 243 GiB/device temp on the
    qwen2-72b train step — §Perf iteration T1). The constraint also pins the
    cotangent sharding, which is what shards the scanned gradient stack.
    """
    plan = current_plan()
    if plan is None:
        return params
    sh = named_sharding_tree(params, plan, rules)
    return jax.tree.map(jax.lax.with_sharding_constraint, params, sh)


def shard(x, *logical_axes):
    """Constrain activation sharding by logical axis names (None = replicate
    that dim). No-op when no plan is active (single-device tests)."""
    plan = current_plan()
    if plan is None:
        return x
    spec = P(*[plan.resolve(a) for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules: path-regex -> logical spec per dim.
# Param paths look like "layers/attn/wq/w", "layers/moe/experts/up", etc.
# Stacked layer params have a leading L dim -> logical None prepended
# automatically when the rule has one fewer axis than the array rank.
# ---------------------------------------------------------------------------

DEFAULT_RULES = [
    # embeddings / lm head: vocab sharded over model axis
    (r"embed/table$", ("model", "fsdp")),
    (r"lm_head/w$", ("fsdp", "model")),
    # attention projections: column-parallel qkv, row-parallel o
    (r"(attn|xattn|shared_attn)/wq/w$", ("fsdp", "model")),
    (r"(attn|xattn|shared_attn)/wk/w$", ("fsdp", "model")),
    (r"(attn|xattn|shared_attn)/wv/w$", ("fsdp", "model")),
    (r"(attn|xattn|shared_attn)/w[qkv]/b$", ("model",)),
    (r"(attn|xattn|shared_attn)/wo/w$", ("model", "fsdp")),
    (r"(attn|xattn|shared_attn)/wo/b$", (None,)),
    # mlp: column-parallel gate/up, row-parallel down
    (r"(mlp|shared_mlp)/(gate|up)/w$", ("fsdp", "model")),
    (r"(mlp|shared_mlp)/down/w$", ("model", "fsdp")),
    (r"(mlp|shared_mlp)/(gate|up|down)/b$", (None,)),
    # MoE: experts dim over expert axis, then like mlp
    (r"experts/(gate|up)$", ("expert", "fsdp", None)),
    (r"experts/down$", ("expert", None, "fsdp")),
    (r"router/w$", (None, "expert")),
    # mamba: d_inner sharded over model
    (r"ssm/in_proj/w$", ("fsdp", "model")),
    (r"ssm/out_proj/w$", ("model", "fsdp")),
    (r"ssm/(x_proj|dt_proj)/w$", ("model", None)),
    (r"ssm/dt_proj/b$", (None,)),
    (r"ssm/(conv_w)$", (None, "model")),
    (r"ssm/(conv_b|A_log|D|dt_bias)$", ("model",)),
    # quantized linears (serving): packed is [N(out), bytes]
    (r"(wq|wk|wv|gate|up)/qw/(packed|scale|zero_prime)", ("model",)),
    (r"(wo|down)/qw/packed$", (None, "model")),
    (r"(wo|down)/qw/(scale|zero_prime)$", (None,)),
    (r"lm_head/qw/(packed|scale|zero_prime)", ("model",)),
    # norms / small vectors replicated
    (r".*", (None,)),
]


def _spec_for(path: str, shape, rules) -> Tuple[Optional[str], ...]:
    for pat, spec in rules:
        if re.search(pat, path):
            spec = tuple(spec)
            if len(spec) < len(shape):  # stacked layer/group leading dims
                spec = (None,) * (len(shape) - len(spec)) + spec
            elif len(spec) > len(shape):
                spec = spec[-len(shape):] if len(shape) else ()
            # never shard a dim that isn't divisible — fall back to replicate
            return spec
    return (None,) * len(shape)


def param_spec_tree(params, rules=None):
    """Pytree of logical specs (tuples of logical axis names) for params."""
    rules = rules or DEFAULT_RULES
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(_spec_for(pstr, getattr(leaf, "shape", ()), rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding_tree(params, plan: AxisPlan, rules=None):
    """Pytree of NamedSharding for params under the plan (divisibility-safe:
    any dim that does not divide by its mesh axis size is replicated)."""
    rules = rules or DEFAULT_RULES
    mesh = plan.mesh
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def to_sharding(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        logical = _spec_for(pstr, getattr(leaf, "shape", ()), rules)
        phys = []
        for dim, l in zip(getattr(leaf, "shape", ()), logical):
            ax = plan.resolve(l)
            if ax is None:
                phys.append(None)
                continue
            size = (axis_sizes[ax] if isinstance(ax, str)
                    else int(__import__("math").prod(axis_sizes[a] for a in ax)))
            phys.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*phys))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [to_sharding(p, l) for p, l in flat])
