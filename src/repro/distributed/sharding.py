"""Logical-axis sharding rules → NamedSharding / PartitionSpec.

The model code annotates activations with *logical* axes via :func:`shard`
(no-op outside a mesh context), and parameters are matched by path patterns
to logical specs which an :class:`AxisPlan` maps onto physical mesh axes.

Physical meshes (launch/mesh.py):
  single-pod (16, 16)      axes ("data", "model")
  multi-pod  (2, 16, 16)   axes ("pod", "data", "model")
  serving    (data, model) over however many devices the host exposes

The plan maps logical -> physical:
  batch   -> ("pod", "data")   (pod composes with data for all batch ops)
  model   -> "model"           (TP: attention heads / ffn / vocab)
  expert  -> "model"           (EP shares the TP axis by default)
  fsdp    -> "data"            (ZeRO-3 parameter sharding over data)
  seq     -> "data"            (sequence parallelism for long prefill)
  stage   -> "pp"              (pipeline axis when a 3D (pp,...) mesh is used)

Packed low-bit weights (core/quantize.QuantizedWeight) flatten with named
child paths (".../qw/packed" etc.), and their rules mirror the float ones:
a column-parallel float weight [K, N] sharded ("fsdp", "model") becomes a
packed plane [N, ceil(K·B/8)] sharded ("model", None) — the quantizer packs
output-major — while a row-parallel weight shards the byte dim, which is
only legal on bit-group boundaries (see :func:`resolve_physical_spec`).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisPlan", "plan_scope", "current_plan", "shard",
           "param_spec_tree", "named_sharding_tree", "constrain_tree",
           "resolve_physical_spec", "packed_group_bytes", "DEFAULT_RULES"]

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class AxisPlan:
    mesh: Mesh
    batch: Tuple[str, ...] = ("data",)
    model: Optional[str] = "model"
    expert: Optional[str] = "model"
    fsdp: Optional[str] = None          # set to "data" for ZeRO-3
    seq: Optional[str] = None           # set to "data" for sequence parallelism
    stage: Optional[str] = None         # set to "pp" for pipeline meshes

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch if len(self.batch) > 1 else self.batch[0]
        return getattr(self, logical)

    def axis_size(self, logical: Optional[str]) -> int:
        """Number of shards the resolved physical axis produces (1 = off)."""
        ax = self.resolve(logical)
        if ax is None:
            return 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if isinstance(ax, str):
            return sizes[ax]
        return int(math.prod(sizes[a] for a in ax))


@contextlib.contextmanager
def plan_scope(plan: Optional[AxisPlan]):
    prev = getattr(_state, "plan", None)
    _state.plan = plan
    try:
        yield plan
    finally:
        _state.plan = prev


def current_plan() -> Optional[AxisPlan]:
    return getattr(_state, "plan", None)


def constrain_tree(params, rules=None):
    """Apply rule-based sharding constraints to a param(-slice) tree.

    Used inside scan-over-layers bodies: without it XLA's SPMD propagation
    frequently loses the sharding of per-layer param slices inside the while
    loop, replicating both the forward all-gather result AND the backward
    grad-accumulation buffers (observed: 243 GiB/device temp on the
    qwen2-72b train step — §Perf iteration T1). The constraint also pins the
    cotangent sharding, which is what shards the scanned gradient stack.
    """
    plan = current_plan()
    if plan is None:
        return params
    sh = named_sharding_tree(params, plan, rules)
    return jax.tree.map(jax.lax.with_sharding_constraint, params, sh)


def shard(x, *logical_axes):
    """Constrain activation sharding by logical axis names (None = replicate
    that dim). No-op when no plan is active (single-device tests)."""
    plan = current_plan()
    if plan is None:
        return x
    spec = P(*[plan.resolve(a) for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules: path-regex -> logical spec per dim.
# Param paths look like "layers/attn/wq/w", "layers/moe/experts/up", etc.
# Stacked layer params have a leading L dim -> logical None prepended
# automatically when the rule has one fewer axis than the array rank.
#
# Quantized leaves: QuantizedWeight flattens to named children, so packed
# serving trees yield paths "layers/attn/wq/qw/packed" / ".../qw/scale" /
# ".../qw/zero_prime" / ".../qw/cw". packed is uint8 [N, ceil(K·B/8)] with
# N = d_out (the quantizer consumes w.T), scale/zero_prime are [N], and cw
# is the offline combined-lookup matrix [G·E, N] (group-major rows, so a
# K-shard is a contiguous row block).
#
# Every parameter leaf MUST match a rule: there is deliberately no ".*"
# catch-all, and an unmatched leaf raises with its key path (same style as
# the kvcache.batch_axes keyed errors) — a silently replicated 72B-scale
# weight is a perf bug that otherwise only shows up as OOM much later.
# ---------------------------------------------------------------------------

DEFAULT_RULES = [
    # embeddings / positional tables / lm head: vocab sharded over model axis
    (r"embed/table$", ("model", "fsdp")),
    (r"pos_embed$", (None, None)),
    (r"lm_head/w$", ("fsdp", "model")),
    (r"lm_head/qw/packed$", ("model", None)),
    (r"lm_head/qw/(scale|zero_prime)$", ("model",)),
    (r"lm_head/qw/cw$", (None, "model")),
    # attention projections: column-parallel qkv, row-parallel o
    (r"(attn|xattn|shared_attn)/wq/w$", ("fsdp", "model")),
    (r"(attn|xattn|shared_attn)/wk/w$", ("fsdp", "model")),
    (r"(attn|xattn|shared_attn)/wv/w$", ("fsdp", "model")),
    (r"(attn|xattn|shared_attn)/w[qkv]/b$", ("model",)),
    (r"(attn|xattn|shared_attn)/wo/w$", ("model", "fsdp")),
    (r"(attn|xattn|shared_attn)/wo/b$", (None,)),
    # mlp: column-parallel gate/up, row-parallel down
    (r"(mlp|shared_mlp)/(gate|up)/w$", ("fsdp", "model")),
    (r"(mlp|shared_mlp)/down/w$", ("model", "fsdp")),
    (r"(mlp|shared_mlp)/(gate|up|down)/b$", (None,)),
    # MoE: experts dim over expert axis, then like mlp
    (r"experts/(gate|up)$", ("expert", "fsdp", None)),
    (r"experts/down$", ("expert", None, "fsdp")),
    (r"experts/(gate|up|down)_qw/packed$", ("expert", None, None)),
    (r"experts/(gate|up|down)_qw/(scale|zero_prime)$", ("expert", None)),
    (r"experts/(gate|up|down)_qw/cw$", ("expert", None, None)),
    (r"router/w$", (None, "expert")),
    # mamba: d_inner sharded over model
    (r"ssm/in_proj/w$", ("fsdp", "model")),
    (r"ssm/out_proj/w$", ("model", "fsdp")),
    (r"ssm/(x_proj|dt_proj)/w$", ("model", None)),
    (r"ssm/dt_proj/b$", (None,)),
    (r"ssm/(conv_w)$", (None, "model")),
    (r"ssm/(conv_b|A_log|D|dt_bias|norm_g)$", ("model",)),
    # quantized linears (serving): packed is [N(out), ceil(K·B/8)].
    # column-parallel (the float weight sharded its OUT dim over model):
    (r"(/|^)(wq|wk|wv|gate|up|in_proj)/qw/packed$", ("model", None)),
    (r"(/|^)(wq|wk|wv|gate|up|in_proj)/qw/(scale|zero_prime)$", ("model",)),
    (r"(/|^)(wq|wk|wv|gate|up|in_proj)/qw/cw$", (None, "model")),
    # row-parallel (the float weight sharded its IN dim over model): shard
    # the byte dim — legal only on bit-group boundaries, enforced by
    # resolve_physical_spec. x_proj/dt_proj read the model-sharded d_inner.
    (r"(/|^)(wo|down|out_proj|x_proj|dt_proj)/qw/packed$", (None, "model")),
    (r"(/|^)(wo|down|out_proj|x_proj|dt_proj)/qw/(scale|zero_prime)$", (None,)),
    (r"(/|^)(wo|down|out_proj|x_proj|dt_proj)/qw/cw$", ("model", None)),
    # norms / gates / small vectors replicated
    (r"norm/(g|b)$", (None,)),
    (r"gate_(attn|mlp)$", (None,)),
    (r"/b$", (None,)),
]


def _key_str(k) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (QuantizedWeight
    # children) -> .name, FlattenedIndexKey -> .key
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


def _spec_for(path: str, shape, rules) -> Optional[Tuple[Optional[str], ...]]:
    """Logical spec for a leaf, or None when no rule matches."""
    for pat, spec in rules:
        if re.search(pat, path):
            spec = tuple(spec)
            if len(spec) < len(shape):  # stacked layer/group leading dims
                spec = (None,) * (len(shape) - len(spec)) + spec
            elif len(spec) > len(shape):
                spec = spec[-len(shape):] if len(shape) else ()
            return spec
    return None


def _spec_leaves(params, rules):
    """[(path, leaf, logical_spec)] for every leaf; raises listing every
    unmatched leaf by key path."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out, unmatched = [], []
    for path, leaf in flat:
        pstr = _path_str(path)
        spec = _spec_for(pstr, getattr(leaf, "shape", ()), rules)
        if spec is None:
            unmatched.append(jax.tree_util.keystr(path))
        out.append((path, leaf, spec))
    if unmatched:
        raise ValueError(
            "no sharding rule matched these parameter leaves (add a rule or "
            "an explicit replicate entry): " + ", ".join(unmatched))
    return out, treedef


def param_spec_tree(params, rules=None):
    """Pytree of logical specs (tuples of logical axis names) for params."""
    leaves, treedef = _spec_leaves(params, rules or DEFAULT_RULES)
    return jax.tree_util.tree_unflatten(treedef, [s for _, _, s in leaves])


def packed_group_bytes(qw) -> int:
    """Bytes one k-group occupies in a packed plane row — the granularity
    below which the byte dim of ``packed`` must never be split."""
    g = max(1, qw.k_total // qw.k_group)
    last = qw.packed.shape[-1] if qw.packed is not None else 0
    return max(1, last // g) if last % g == 0 and last else 1


def resolve_physical_spec(shape, phys_axes, axis_sizes,
                          *, last_dim_align: int = 1):
    """Pure resolver: per-dim physical axis names -> a legal PartitionSpec
    tuple for ``shape``.

    A dim is replicated (None) when its mesh axis does not evenly divide
    it.  ``last_dim_align`` additionally requires the per-shard extent of
    the FINAL dim to be a multiple of the given alignment — used for packed
    low-bit planes, where a byte-dim shard boundary inside a bit-group
    would split a group code across devices (the never-mid-byte /
    never-mid-group rule).  GSPMD shardings are layout-only, so falling
    back to replication is always semantics-preserving.
    """
    out = []
    ndim = len(shape)
    for i, (dim, ax) in enumerate(zip(shape, phys_axes)):
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, str):
            size = axis_sizes[ax]
        else:
            size = int(math.prod(axis_sizes[a] for a in ax))
        if size <= 0 or dim % size != 0:
            out.append(None)
            continue
        if i == ndim - 1 and last_dim_align > 1 and \
                (dim // size) % last_dim_align != 0:
            out.append(None)
            continue
        out.append(ax)
    return tuple(out)


def _packed_align_map(params):
    """path-prefix (of the qw node) -> group-byte alignment, from a pre-walk
    over QuantizedWeight nodes (their static metadata is invisible once the
    tree is flattened to array leaves)."""
    from repro.core.quantize import QuantizedWeight
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedWeight))
    return {_path_str(path): packed_group_bytes(leaf)
            for path, leaf in flat if isinstance(leaf, QuantizedWeight)}


def named_sharding_tree(params, plan: AxisPlan, rules=None):
    """Pytree of NamedSharding for params under the plan.

    Divisibility-safe: any dim that does not divide by its mesh axis size is
    replicated, and the byte dim of a packed plane is only sharded when
    every shard covers whole bit-groups (see :func:`resolve_physical_spec`).
    """
    mesh = plan.mesh
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    align = _packed_align_map(params)

    leaves, treedef = _spec_leaves(params, rules or DEFAULT_RULES)
    out = []
    for path, leaf, logical in leaves:
        pstr = _path_str(path)
        last_align = 1
        if pstr.endswith("/packed"):
            last_align = align.get(pstr[:-len("/packed")], 1)
        phys = resolve_physical_spec(
            getattr(leaf, "shape", ()),
            [plan.resolve(l) for l in logical],
            axis_sizes, last_dim_align=last_align)
        out.append(NamedSharding(mesh, P(*phys)))
    return jax.tree_util.tree_unflatten(treedef, out)
