"""TPU v5e hardware constants for the roofline model (per task spec)."""

PEAK_BF16_FLOPS = 197e12      # FLOP/s per chip
PEAK_INT8_OPS = 394e12        # OP/s per chip (2x bf16 on the MXU)
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link
HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
VMEM_BYTES = 128 * 2 ** 20    # ~128 MiB per chip

# effective per-link traffic multiplier by collective type (ring algorithms)
RING_FACTOR = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
