"""Three-term roofline from a compiled XLA artifact.

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = Σ collective_bytes × ring_factor / link_bw   (per-chip HLO)

``cost_analysis()`` supplies per-device FLOPs/bytes of the partitioned
module; collective bytes are parsed from the *post-optimization* HLO text
(``compiled.as_text()``) — the pre-partitioning stableHLO has no collectives
yet. Shapes in HLO are per-device, so per-chip terms divide by link/HBM
bandwidth directly (the global forms in the task spec cancel chips).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes of every collective in (per-device) HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        if m.group(0).find(f"{kind}-done(") >= 0:
            continue  # avoid double counting async start/done pairs
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes: Dict[str, int]
    n_devices: int
    # terms in seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0        # 6·N·D (train) or 2·N·D (inference)
    peak_flops: float = hw.PEAK_BF16_FLOPS
    min_bytes: float = 0.0          # lower bound: args read + non-aliased out

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_devices
        return (self.model_flops / total) if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Roofline proximity of the step.

        The step's *ideal* time is whichever hardware limit binds its
        irreducible work: useful-FLOPs time (compute roofline) or
        minimum-traffic time (memory roofline — the binding one for decode,
        where the step MUST stream params+cache once). Fraction =
        max(ideal terms) / achieved bound time.
        """
        if self.t_bound <= 0:
            return 0.0
        t_ideal_c = self.model_flops / (self.n_devices * self.peak_flops)
        t_ideal_m = self.min_bytes / hw.HBM_BW
        return min(1.0, max(t_ideal_c, t_ideal_m) / self.t_bound)

    @property
    def memory_efficiency(self) -> float:
        """min necessary HBM traffic / achieved traffic (1.0 = no waste)."""
        return (self.min_bytes / self.bytes_per_device
                if self.bytes_per_device else 0.0)

    def to_dict(self):
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes": self.coll_bytes,
            "n_devices": self.n_devices,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "min_bytes": self.min_bytes,
            "memory_efficiency": self.memory_efficiency,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, *, n_devices: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None, int8_fraction: float = 0.0,
            min_bytes: float = 0.0) -> Roofline:
    """Build the roofline from a compiled executable.

    int8_fraction: fraction of FLOPs running at the int8 MXU rate (the
    LUT-as-int8-GEMM path) — raises the effective compute ceiling.
    """
    # Loop-aware costing (roofline/hlo_cost.py): XLA's flat cost_analysis
    # counts while bodies once — wrong by the trip count for scanned layers,
    # microbatches and flash chunks. Flat numbers kept for reference.
    from repro.roofline import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    lc = hlo_cost.analyze_text(text)
    flops, bts, coll = lc.flops, lc.bytes, dict(lc.coll)

    peak = (hw.PEAK_BF16_FLOPS * (1 - int8_fraction)
            + hw.PEAK_INT8_OPS * int8_fraction)
    t_comp = flops / peak
    t_mem = bts / hw.HBM_BW
    t_coll = sum(hw.RING_FACTOR.get(k, 1.0) * v for k, v in coll.items()) \
        / hw.ICI_LINK_BW
    return Roofline(flops, bts, coll, n_devices,
                    t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
                    model_flops=model_flops, peak_flops=peak,
                    min_bytes=min_bytes)
