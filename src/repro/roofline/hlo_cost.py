"""Loop-aware cost analysis of post-optimization HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE — for
scan-over-layers / microbatch / flash-chunk programs that undercounts
FLOPs, bytes and collective traffic by the trip count (validated:
an 8-step scanned matmul reports 1/8 the flops of its unrolled twin).

This walker parses the HLO text into computations, walks the call graph
from ENTRY, and multiplies every ``while`` body+condition by the loop's
trip count (recovered from the ``constant(N)`` bound in the condition
region — scans always lower to ``iv < N``).

Costing rules:
  * flops: ``dot`` ops only (2 · Πresult · Πcontracting), recursing into
    fusion-called computations (dots stay unfused on the CPU backend we
    compile with; elementwise flops are ignored — MXU work is the term
    that matters for t_compute);
  * bytes: per materializing instruction, result + operand bytes; pure
    plumbing (parameter/gte/tuple/bitcast/constant/while/conditional)
    excluded; fusion counts only its boundary buffers (post-fusion
    semantics, same as XLA's own "bytes accessed");
  * collectives: result bytes per kind, × enclosing trip counts.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.+\{\s*$")
# result type is either a tuple "( ... )" (may contain /*index=N*/ comments,
# so match to the first closing paren — tuple types never nest parens) or a
# plain shape "dtype[dims]{layout}".
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_ATTR_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w\.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT = re.compile(r"s32\[\]\s+constant\((\d+)\)")

# NOTE: "convert" is treated as free: on the CPU backend we compile with,
# XLA legalizes every bf16 op by round-tripping whole buffers through f32
# (verified: the pre-optimization module has no such converts) — on the TPU
# target bf16 is native and converts fuse into consumers.
_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "while", "conditional", "after-all", "iota",
               "partition-id", "replica-id", "convert"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for line in text.splitlines():
            h = _COMP_HDR.match(line)
            if h:
                cur = h.group(2)
                self.comps[cur] = []
                if h.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.startswith("}"):
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                self.comps[cur].append(Instr(*m.groups()))

    # -- helpers --------------------------------------------------------------
    def _types(self, comp: str) -> Dict[str, str]:
        return {i.name: i.result_type for i in self.comps[comp]}

    def _trip_count(self, cond_comp: str) -> int:
        consts = []
        for i in self.comps.get(cond_comp, []):
            consts += [int(x) for x in _CONSTANT.findall(
                f"%{i.name} = {i.result_type} {i.opcode}({i.rest}")]
        return max(consts) if consts else 1

    def _dot_flops(self, instr: Instr, types: Dict[str, str]) -> float:
        res = _shape_dims(instr.result_type)
        out = 1.0
        for d in res:
            out *= d
        contract = 1.0
        m = _CONTRACT.search(instr.rest)
        ops = _OPERAND.findall(instr.rest.split(")")[0])
        if m and ops:
            lhs_dims = _shape_dims(types.get(ops[0], ""))
            for ax in m.group(1).split(","):
                if ax and int(ax) < len(lhs_dims):
                    contract *= lhs_dims[int(ax)]
        return 2.0 * out * contract

    # -- sliced-access byte accounting -----------------------------------------
    # XLA's HloCostAnalysis charges dynamic-slice the SLICE bytes (not the
    # whole operand) and dynamic-update-slice the UPDATE bytes (in-place
    # read-modify-write); gathers/scatters likewise move ~result/update-sized
    # traffic. Without this, every scan iteration would be charged the full
    # stacked weight/cache buffer it slices one layer out of.

    def _operands(self, i: Instr) -> List[str]:
        return _OPERAND.findall(i.rest.split(")")[0])

    def _plain_bytes(self, i: Instr, types: Dict[str, str],
                     producers: Optional[Dict[str, "Instr"]] = None) -> float:
        op = i.opcode
        ops = self._operands(i)
        if op == "dynamic-slice":
            return 2.0 * _shape_bytes(i.result_type)  # read slice + write out
        if op == "dynamic-update-slice":
            upd = types.get(ops[1], "") if len(ops) > 1 else ""
            return 2.0 * _shape_bytes(upd)            # rmw the update region
        if op == "gather":
            return 2.0 * _shape_bytes(i.result_type)
        if op == "scatter":
            upd = types.get(ops[-1], "") if ops else ""
            return 3.0 * _shape_bytes(upd)
        b = float(_shape_bytes(i.result_type))
        for o in ops:
            t = types.get(o, "")
            if op == "dot" and producers is not None:
                # charge dot operands at their PRE-convert dtype: the CPU
                # backend promotes bf16/int8 operands to f32 buffers that a
                # TPU reads natively (fused converts).
                seen = 0
                name = o
                while seen < 4:
                    prod = producers.get(name)
                    if prod is None or prod.opcode not in ("convert", "copy",
                                                           "bitcast"):
                        break
                    nxt = self._operands(prod)
                    if not nxt:
                        break
                    name = nxt[0]
                    seen += 1
                t = types.get(name, t)
            b += _shape_bytes(t)
        return b

    def _fusion_bytes(self, i: Instr, types: Dict[str, str]) -> float:
        """Boundary traffic of a fusion: slice-aware per operand, update-
        aware for a DUS root (in-place aliasing)."""
        called = _ATTR_CALLS.search(i.rest)
        ops = self._operands(i)
        if not called or called.group(1) not in self.comps:
            b = float(_shape_bytes(i.result_type))
            for o in ops:
                b += _shape_bytes(types.get(o, ""))
            return b
        comp = self.comps[called.group(1)]
        ctypes = {x.name: x.result_type for x in comp}
        # map parameter index -> instr name
        params = {}
        for x in comp:
            if x.opcode == "parameter":
                m = re.match(r"(\d+)", x.rest)
                if m:
                    params[int(m.group(1))] = x.name
        # consumers of each named value, looking THROUGH bitcasts (free)
        direct: Dict[str, List[Instr]] = {}
        for x in comp:
            for o in self._operands(x):
                direct.setdefault(o, []).append(x)

        def effective_consumers(name, depth=0):
            out = []
            for x in direct.get(name, []):
                if x.opcode in ("bitcast", "convert") and depth < 8:
                    out += effective_consumers(x.name, depth + 1)
                else:
                    out.append(x)
            return out

        consumers = {x.name: effective_consumers(x.name) for x in comp}
        for idx, pname in params.items():
            consumers[pname] = effective_consumers(pname)
        root = comp[-1] if comp else None
        # unwrap convert/copy/bitcast chains: CPU bf16 legalization wraps the
        # real root (often a DUS) in dtype round-trips
        seen = 0
        while root is not None and root.opcode in ("convert", "copy", "bitcast") and seen < 8:
            src = (self._operands(root) or [None])[0]
            root = next((x for x in comp if x.name == src), None)
            seen += 1
        dus_aliased_param = None
        if root is not None and root.opcode == "dynamic-update-slice":
            rops = self._operands(root)
            # operand 0 (possibly via bitcast) aliases the output in place
            src = rops[0] if rops else None
            while src is not None:
                hit = next((x for x in comp if x.name == src), None)
                if hit is not None and hit.opcode in ("bitcast", "copy"):
                    src = (self._operands(hit) or [None])[0]
                    continue
                break
            for idx, pname in params.items():
                if pname == src:
                    dus_aliased_param = idx
            upd = ctypes.get(rops[1], "") if len(rops) > 1 else ""
            b = 2.0 * _shape_bytes(upd)
        else:
            b = float(_shape_bytes(i.result_type))
        for k, o in enumerate(ops):
            if k == dus_aliased_param:
                continue  # in-place buffer: charged via the update bytes
            pname = params.get(k)
            cons = consumers.get(pname, []) if pname else []
            if cons and all(c.opcode == "dynamic-slice" for c in cons):
                b += sum(_shape_bytes(c.result_type) for c in cons)
            else:
                b += _shape_bytes(types.get(o, ""))
        return b

    # -- recursive walk --------------------------------------------------------
    def cost_of(self, comp: str, _depth=0) -> Cost:
        return self._cost_cached(comp)

    @lru_cache(maxsize=None)  # type: ignore[misc]
    def _cost_cached(self, comp: str) -> Cost:
        total = Cost()
        types = self._types(comp)
        producers = {i.name: i for i in self.comps.get(comp, [])}
        for i in self.comps.get(comp, []):
            op = i.opcode
            if op == "while":
                body = _ATTR_BODY.search(i.rest)
                cond = _ATTR_COND.search(i.rest)
                trip = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    total += self._cost_cached(body.group(1)).scaled(trip)
                if cond:
                    total += self._cost_cached(cond.group(1)).scaled(trip)
                continue
            if op in ("fusion", "custom-call"):
                total += Cost(bytes=self._fusion_bytes(i, types))
                c = _ATTR_CALLS.search(i.rest)
                if c:  # flops (dots) inside the fused computation
                    total += Cost(flops=self._cost_cached(c.group(1)).flops)
                continue
            if op == "call":
                c = _ATTR_CALLS.search(i.rest) or _ATTR_CALLS.search(
                    "calls=" + i.rest.split("to_apply=")[-1])
                if c:
                    total += self._cost_cached(c.group(1))
                continue
            if op == "conditional":
                continue  # branches rare here; skipped (documented)
            is_coll = any(op.startswith(k) for k in COLLECTIVES)
            if is_coll and op.endswith("-done"):
                continue
            if op == "dot":
                total += Cost(flops=self._dot_flops(i, types))
            if op not in _SKIP_BYTES:
                total += Cost(bytes=self._plain_bytes(i, types, producers))
            if is_coll:
                kind = next(k for k in COLLECTIVES if op.startswith(k))
                total += Cost(coll={kind: float(_shape_bytes(i.result_type))})
        return total


def analyze_text(text: str) -> Cost:
    mod = HloModule(text)
    if mod.entry is None:
        return Cost()
    return mod.cost_of(mod.entry)
