"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""

from __future__ import annotations

from typing import Optional

from repro.distributed._compat import make_mesh
from repro.distributed.sharding import AxisPlan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_pipeline_mesh(*, pp: int = 4, data: int = 8, model: int = 16):
    """3D mesh with a pipeline axis (pp × data × model)."""
    return make_mesh((pp, data, model), ("pp", "data", "model"))


def make_serving_mesh(*, data: int = 1, model: Optional[int] = None):
    """A (data, model) mesh over however many devices the host exposes.

    ``model=None`` uses every device not consumed by ``data``. The
    single-device default collapses to a 1×1 mesh, for which
    :func:`make_plan` yields a no-op plan (every axis has size 1, so every
    sharding constraint resolves to replication).
    """
    import jax
    n = jax.device_count()
    if model is None:
        if n % max(1, data):
            raise ValueError(f"data={data} does not divide device count {n}")
        model = n // max(1, data)
    if data * model != n:
        raise ValueError(
            f"mesh {data}x{model} needs {data * model} devices, have {n}")
    return make_mesh((data, model), ("data", "model"))


def make_plan(mesh, *, fsdp: bool = True, seq_parallel: bool = False) -> AxisPlan:
    multi_pod = "pod" in mesh.axis_names
    return AxisPlan(
        mesh=mesh,
        batch=("pod", "data") if multi_pod else ("data",),
        model="model",
        expert="model",
        fsdp="data" if fsdp else None,
        seq="data" if seq_parallel else None,
        stage="pp" if "pp" in mesh.axis_names else None,
    )
