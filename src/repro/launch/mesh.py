"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import AxisType

from repro.distributed.sharding import AxisPlan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_pipeline_mesh(*, pp: int = 4, data: int = 8, model: int = 16):
    """3D mesh with a pipeline axis (pp × data × model)."""
    return jax.make_mesh((pp, data, model), ("pp", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)


def make_plan(mesh, *, fsdp: bool = True, seq_parallel: bool = False) -> AxisPlan:
    multi_pod = "pod" in mesh.axis_names
    return AxisPlan(
        mesh=mesh,
        batch=("pod", "data") if multi_pod else ("data",),
        model="model",
        expert="model",
        fsdp="data" if fsdp else None,
        seq="data" if seq_parallel else None,
        stage="pp" if "pp" in mesh.axis_names else None,
    )
