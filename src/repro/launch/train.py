"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 300 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Wires together: config → params → optimizer → train step (QAT fwd) → data
pipeline → checkpoint/restart manager → (optional) mesh + pjit shardings.
On this CPU container use --reduced for real steps; the full configs are
exercised by the dry-run.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed.sharding import plan_scope
from repro.launch.mesh import make_plan, make_production_mesh
from repro.training import checkpoint as ckpt_mod
from repro.training import optimizer as opt_mod
from repro.training.data import Prefetcher, SyntheticLM
from repro.training.train_loop import (init_train_state, make_train_step,
                                       train_shardings)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adamw8bit", "adafactor", "momentum"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-feasible)")
    ap.add_argument("--no-qat", action="store_true",
                    help="disable the paper's QAT fake-quant forward")
    ap.add_argument("--grad-compression", default=None, choices=[None, "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    cfg = cfg.replace(activation_dtype=jnp.float32)

    sched = opt_mod.lr_schedule(args.lr, warmup=20, total=args.steps)
    opt = opt_mod.make_optimizer(args.optimizer, lr=sched)
    step_fn = make_train_step(cfg, opt, microbatches=args.microbatches,
                              grad_compression=args.grad_compression,
                              qat=not args.no_qat)

    state = init_train_state(jax.random.key(0), cfg, opt,
                             grad_compression=args.grad_compression)
    start = 0
    rm = None
    if args.ckpt_dir:
        rm = ckpt_mod.RestartManager(args.ckpt_dir, every=args.ckpt_every)
        restored, start = rm.restore_or_none(state)
        if restored is not None:
            state = restored
            print(f"resumed from step {start}")

    plan = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        plan = make_plan(mesh)
        sh = train_shardings(state, plan)
        state = jax.tree.map(jax.device_put, state, sh)

    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    data = Prefetcher(SyntheticLM(cfg.vocab_size, args.batch, args.seq),
                      start_step=start)

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.next())
        with plan_scope(plan):
            state, metrics = jit_step(state, batch)
        losses.append(float(metrics["loss"]))
        if rm:
            rm.maybe_save(step + 1, state)
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(f"step {step+1:5d}  loss {np.mean(losses[-args.log_every:]):.4f}"
                  f"  {dt*1e3:.0f} ms/step", flush=True)
            t0 = time.time()
    if rm:
        rm.maybe_save(args.steps, state, force=True)
        rm.wait()
    data.close()
    if not losses:  # resumed at/after the target step: nothing to run
        print("no steps to run (already at target step)")
        return 0
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} over {len(losses)} steps")
    # short resume windows overlap; only fail on a clear regression
    return 0 if (last <= first * 1.02 or len(losses) < 20) else 1


if __name__ == "__main__":
    raise SystemExit(main())
