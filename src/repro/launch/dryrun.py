import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell, print memory/cost analysis, and emit roofline terms.

MUST be run as its own process (the two lines above must execute before any
jax import anywhere — jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out results.json] [--mode paper|...]

Steps lowered per shape kind:
  train   — full train step (QAT fwd per cfg.quant, loss, grads, optimizer
            update; adafactor for the 1T-param config, adamw otherwise)
  prefill — serve-quantized forward, last-token logits + KV caches out
  decode  — serve-quantized single-token step against an S-long cache

No real arrays are allocated: params/inputs/caches are ShapeDtypeStructs
(jax.eval_shape for the trees), and .lower().compile() proves the sharded
program exists (the pod axis shards in the multi-pod pass).
"""

import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.distributed.sharding import named_sharding_tree, plan_scope
from repro.launch.mesh import make_plan, make_production_mesh
from repro.models import api
from repro.models.transformer import lm_loss
from repro.roofline import analysis
from repro.training import optimizer as opt_mod
from repro.training.train_loop import init_train_state, make_train_step, train_shardings


# ---------------------------------------------------------------------------
# sharding heuristics for inputs/caches
# ---------------------------------------------------------------------------

def _batch_axes(plan):
    return plan.batch if len(plan.batch) > 1 else plan.batch[0]


def input_shardings(specs, plan, batch_size):
    """Tokens/labels/frames: shard dim0 (batch) over the batch axes."""
    mesh = plan.mesh
    bsz = _mesh_size(plan)

    def one(x):
        spec = [None] * len(x.shape)
        if x.shape and x.shape[0] == batch_size and batch_size % bsz == 0:
            spec[0] = _batch_axes(plan)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


def _mesh_size(plan):
    sizes = dict(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
    return int(jnp.prod(jnp.asarray([sizes[a] for a in plan.batch])))


def cache_shardings(cache_specs, plan, batch_size):
    """Caches: batch dim over data axes; a head/feature dim over model.

    Rules (see DESIGN.md §4): attention [*,B,S,KV,hd] shards KV over model
    when divisible else hd; mamba conv [*,B,W,di] shards di; mamba ssm
    states shard d_inner / heads. Any non-divisible dim is replicated.
    """
    mesh = plan.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get(plan.model, 1)
    bsz = _mesh_size(plan)

    def one(path, x):
        pstr = jax.tree_util.keystr(path)
        shape = x.shape
        spec = [None] * len(shape)
        # batch dim: first dim equal to batch_size (skip dim 0 when it's a
        # layer-stack dim of the same size is unlikely; search left to right)
        if batch_size > 1 and batch_size % bsz == 0:
            for i, d in enumerate(shape):
                if d == batch_size:
                    spec[i] = _batch_axes(plan)
                    break
        if "conv" in pstr:
            if shape[-1] % msize == 0:
                spec[-1] = plan.model
        elif "ssm" in pstr or "mamba" in pstr or "tail" in pstr:
            if shape[-2] % msize == 0 and spec[-2] is None:
                spec[-2] = plan.model
            elif shape[-1] % msize == 0 and spec[-1] is None:
                spec[-1] = plan.model
        elif len(shape) >= 4:
            # attention caches [*, B, S, KV, hd] (+ scale [*, B, S, KV, 1]):
            # shard the SEQUENCE over model for flash-decode (§Perf B4);
            # fall back to kv/hd sharding when S is not divisible.
            if shape[-3] % msize == 0 and spec[-3] is None and shape[-3] >= msize:
                spec[-3] = plan.model
            elif shape[-2] % msize == 0 and spec[-2] is None:
                spec[-2] = plan.model
            elif shape[-1] % msize == 0 and spec[-1] is None and shape[-1] > 1:
                spec[-1] = plan.model
        elif len(shape) >= 3:
            if shape[-2] % msize == 0 and spec[-2] is None:
                spec[-2] = plan.model
            elif shape[-1] % msize == 0 and spec[-1] is None:
                spec[-1] = plan.model
        return NamedSharding(mesh, P(*spec))

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_specs)
    return jax.tree_util.tree_unflatten(
        tdef, [one(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# step builders (lower-ready closures over cfg)
# ---------------------------------------------------------------------------

def train_microbatches(cfg) -> int:
    """Gradient-accumulation policy (§Perf T2): the scan-over-layers carry
    saves B·S·D bytes per layer for backward; microbatching divides it.
    Measured on qwen2-72b(4L): temp 32.8 -> 6.6 GiB/dev at 8 microbatches,
    and t_collective also fell 3x (per-microbatch FSDP gathers pipeline)."""
    n = cfg.num_params()
    if n > 2e10:
        return 16
    if n > 2e9:
        return 8
    return 4


def build_train(cfg, plan):
    opt_name = "adafactor" if cfg.arch_id.startswith("kimi") else "adamw"
    opt = opt_mod.make_optimizer(opt_name, lr=1e-4)
    step_fn = make_train_step(cfg, opt, qat=True,
                              microbatches=train_microbatches(cfg))
    state_specs = jax.eval_shape(
        functools.partial(init_train_state, cfg=cfg, opt=opt),
        jax.random.key(0))
    state_sh = train_shardings(state_specs, plan)

    def fn(state, batch):
        with plan_scope(plan):
            return step_fn(state, batch)

    return fn, state_specs, state_sh


def build_prefill(cfg, plan, shape):
    serve_q = not (cfg.quant or {}).get("mpgemm_mode") == "fp16"
    pspecs = api.param_specs(cfg, serve_quantized=serve_q)
    p_sh = named_sharding_tree(pspecs, plan)
    cspecs = api.cache_specs(cfg, shape)
    c_sh = cache_shardings(cspecs, plan, shape.global_batch)

    def fn(params, caches, batch):
        with plan_scope(plan):
            logits, new_caches, _ = api.forward(
                params, batch, cfg, caches=caches, cache_pos=0,
                window=shape.window)
            return logits[:, -1], new_caches

    return fn, (pspecs, p_sh), (cspecs, c_sh)


def build_decode(cfg, plan, shape):
    serve_q = not (cfg.quant or {}).get("mpgemm_mode") == "fp16"
    pspecs = api.param_specs(cfg, serve_quantized=serve_q)
    p_sh = named_sharding_tree(pspecs, plan)
    cspecs = api.cache_specs(cfg, shape)
    c_sh = cache_shardings(cspecs, plan, shape.global_batch)

    def fn(params, caches, batch):
        with plan_scope(plan):
            logits, new_caches, _ = api.forward(
                params, batch, cfg, caches=caches,
                cache_pos=batch["cache_pos"], window=shape.window)
            return logits[:, -1], new_caches

    return fn, (pspecs, p_sh), (cspecs, c_sh)


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: str = None,
             kv: str = None, store: str = None, k_group: int = None):
    cfg = registry.get_config(arch)
    if mode:  # override the mpGEMM execution mode (hillclimb lever)
        cfg = cfg.with_quant(mpgemm_mode=mode)
    if store:  # "cw": offline-expanded lookup weights (§Perf B1)
        cfg = cfg.with_quant(store=store)
    if k_group:
        cfg = cfg.with_quant(k_group=k_group)
    if kv:  # "int8": quantized KV cache (§Perf B3)
        cfg = cfg.replace(kv_cache_dtype=kv)
    shape = cfg.shape(shape_name)
    if shape.skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": shape.skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    # FSDP (ZeRO-3) only for training: re-gathering serving weights every
    # decode step costs ~16 GiB/step of all-gathers (Perf B5) — inference
    # params are TP-sharded over model and replicated over data.
    plan = make_plan(mesh, fsdp=(shape.kind == "train"))
    n_dev = mesh.devices.size
    t0 = time.time()

    if shape.kind == "train":
        fn, state_specs, state_sh = build_train(cfg, plan)
        in_specs = api.input_specs(cfg, shape)
        in_sh = input_shardings(in_specs, plan, shape.global_batch)
        lowered = jax.jit(fn, in_shardings=(state_sh, in_sh),
                          donate_argnums=(0,)).lower(state_specs, in_specs)
        model_flops = 6 * cfg.active_params() * shape.global_batch * shape.seq_len
    else:
        builder = build_prefill if shape.kind == "prefill" else build_decode
        fn, (pspecs, p_sh), (cspecs, c_sh) = builder(cfg, plan, shape)
        in_specs = api.input_specs(cfg, shape)
        if "cache_pos" in in_specs:
            in_specs = dict(in_specs)
        in_sh = input_shardings(in_specs, plan, shape.global_batch)
        lowered = jax.jit(fn, in_shardings=(p_sh, c_sh, in_sh),
                          donate_argnums=(1,)).lower(pspecs, cspecs, in_specs)
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind == "prefill" else shape.global_batch)
        model_flops = 2 * cfg.active_params() * tokens

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    min_bytes = (mem.argument_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    roof = analysis.analyze(compiled, n_devices=n_dev, model_flops=model_flops,
                            hlo_text=hlo, min_bytes=min_bytes)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "kind": shape.kind,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        "roofline": roof.to_dict(),
    }
    del compiled, lowered, hlo
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default=None,
                    help="override mpgemm mode: fp16|dequant|lut_xla")
    ap.add_argument("--kv", default=None, help="kv cache dtype: int8")
    ap.add_argument("--store", default=None, help="weight store: cw")
    ap.add_argument("--k-group", type=int, default=None)
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else registry.ASSIGNED
    shapes = ([args.shape] if args.shape
              else ["train_4k", "prefill_32k", "decode_32k", "long_500k"])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"], r.get("mode")) for r in results}

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = (arch, shape, "multi" if multi else "single", args.mode)
                if key in done:
                    continue
                label = f"{arch} × {shape} × {'multi' if multi else 'single'}"
                print(f"=== {label} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, multi, args.mode, args.kv,
                                   args.store, args.k_group)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                if args.mode or args.kv or args.store or args.k_group:
                    rec["mode"] = "+".join(filter(None, [
                        args.mode, args.kv and f"kv{args.kv}",
                        args.store and f"store_{args.store}",
                        args.k_group and f"kg{args.k_group}"]))
                results.append(rec)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok: compile {rec['compile_s']}s  "
                          f"peak/dev {rec['memory']['peak_per_device']/2**30:.2f} GiB  "
                          f"t(comp/mem/coll) = {r['t_compute']:.2e}/"
                          f"{r['t_memory']:.2e}/{r['t_collective']:.2e}s  "
                          f"dominant={r['dominant']}  "
                          f"roofline={r['roofline_fraction']:.3f}", flush=True)
                elif rec["status"] == "skipped":
                    print(f"  skipped: {rec['reason']}", flush=True)
                else:
                    print(f"  ERROR: {rec['error']}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n{n_ok} ok, {n_err} errors, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
