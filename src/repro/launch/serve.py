"""Serving driver: quantize a model to the packed low-bit format and serve a
batch of requests through the device-resident continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 12 --max-new 24 --mode lut_xla \
        --decode-chunk 8 --temperature 0.8 --top-k 40 --top-p 0.95
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.mpgemm import FUSION_MODES, MPGEMM_MODES
from repro.models import api
from repro.serving import decoding
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="decode steps per device dispatch (host syncs once "
                         "per chunk, not once per token)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="fixed prompt-chunk shape for admission prefill "
                         "(one compiled program for all prompt lengths)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (<=0 greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k (0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus mass (>=1 disables)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a slot when it samples this token id")
    ap.add_argument("--decoding", default="greedy",
                    help="per-request decoding mode: greedy | sample | "
                         "beam:W (width-W beam search, W pool slots per "
                         "request) | spec:draftNb (bit-plane self-"
                         "speculation drafting with the top N planes of "
                         "the SAME packed weights)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative verify round")
    ap.add_argument("--cache-block-size", type=int, default=None,
                    help="enable the block-paged KV cache pool with this "
                         "many positions per block (must divide --max-seq)")
    ap.add_argument("--num-cache-blocks", type=int, default=None,
                    help="pool size in blocks incl. the reserved null block "
                         "(default: dense-equivalent capacity)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="hash-based shared-prefix block reuse (requires "
                         "--cache-block-size; identical prefixes prefill "
                         "once and fan out by block reference)")
    ap.add_argument("--mode", default="lut_xla",
                    choices=list(MPGEMM_MODES))
    ap.add_argument("--fusion", default="auto",
                    choices=list(FUSION_MODES),
                    help="lut_pallas precompute placement: fused keeps the "
                         "table in VMEM, staged round-trips it through HBM, "
                         "tuned reads the measured autotune cache")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="persistent kernel-tuning cache (JSON). Activates "
                         "measured dispatch for fusion=tuned; created/"
                         "updated by --pretune")
    ap.add_argument("--pretune", action="store_true",
                    help="before serving, measure-tune every mpGEMM shape "
                         "this engine dispatches and persist the cache "
                         "(lut_pallas only)")
    ap.add_argument("--weight-bits", type=int, default=2)
    ap.add_argument("--mesh", default=None, metavar="DXM",
                    help="serving mesh 'data x model', e.g. 2x4: shards "
                         "packed weights / caches / engine state over a "
                         "jax.sharding mesh (needs data*model devices). "
                         "Default is single-device — the 1x1 no-op plan")
    ap.add_argument("--tp", type=int, default=None, metavar="N",
                    help="tensor-parallel shortcut for --mesh 1xN")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the request "
                         "lifecycle (admit/prefill/decode-chunk spans; open "
                         "at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot as JSON "
                         "('-.prom' suffix writes Prometheus text instead)")
    ap.add_argument("--dispatch-log", default=None, metavar="PATH",
                    help="record every mpGEMM dispatch decision (shape key, "
                         "fusion, tuned-vs-heuristic) traced during this "
                         "serve and write it as JSON")
    args = ap.parse_args(argv)

    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    cfg = cfg.replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode=args.mode, weight_bits=args.weight_bits,
                         fusion=args.fusion)

    print(f"init + quantize ({args.mode}, W{args.weight_bits}) ...")
    quantized = args.mode != "fp16"
    params = api.init_params(jax.random.key(0), cfg,
                             serve_quantized=quantized)
    if not quantized:
        cfg = cfg.replace(quant=None)

    if args.fusion == "tuned" and args.tuning_cache is None and not args.pretune:
        print("note: fusion=tuned without --tuning-cache falls back to the "
              "auto heuristic on every dispatch")
    if args.prefix_cache and args.cache_block_size is None:
        ap.error("--prefix-cache requires --cache-block-size")
    try:
        dm = decoding.parse(args.decoding)
    except ValueError as e:
        ap.error(str(e))
    spec_draft_planes = dm.draft_planes if dm.kind == decoding.SPEC else None
    if spec_draft_planes is not None and args.mode == "fp16":
        ap.error("--decoding spec needs a quantized mode: the draft is a "
                 "bit-plane slice of the packed weights")
    if args.mesh is not None and args.tp is not None:
        ap.error("--mesh and --tp are mutually exclusive")
    plan = None
    if args.mesh is not None or args.tp is not None:
        from repro.launch.mesh import make_plan, make_serving_mesh
        if args.mesh is not None:
            try:
                d, m = (int(v) for v in args.mesh.lower().split("x"))
            except ValueError:
                ap.error(f"--mesh wants 'DxM' (e.g. 2x4), got {args.mesh!r}")
        else:
            d, m = 1, args.tp
        mesh = make_serving_mesh(data=d, model=m)
        plan = make_plan(mesh, fsdp=False)
        print(f"serving mesh {d}x{m} (data x model) over "
              f"{jax.device_count()} devices")
    tracer = None
    if args.trace_out is not None:
        from repro.obs.trace import Tracer
        tracer = Tracer()
    recorder = None
    if args.dispatch_log is not None:
        from repro.obs import dispatch as dispatch_obs
        recorder = dispatch_obs.enable(dispatch_obs.DispatchRecorder())
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq,
                        decode_chunk=args.decode_chunk,
                        prefill_chunk=args.prefill_chunk,
                        eos_id=args.eos_id,
                        tuning_cache=args.tuning_cache,
                        cache_block_size=args.cache_block_size,
                        num_cache_blocks=args.num_cache_blocks,
                        prefix_cache=args.prefix_cache,
                        plan=plan,
                        spec_k=args.spec_k,
                        spec_draft_planes=spec_draft_planes,
                        tracer=tracer)
    if args.pretune:
        if eng.tuning_cache is None:  # tune in-memory for this process
            from repro.core import autotune
            eng.tuning_cache = autotune.configure(None)
        t0 = time.time()
        n = eng.pretune(verbose=True)
        print(f"pretuned {n} mpGEMM shapes in {time.time() - t0:.1f}s "
              f"-> {args.tuning_cache or '(in-memory only)'}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=args.max_new, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, decoding=args.decoding))
    t0 = time.time()
    chunks = eng.run_to_completion()
    dt = time.time() - t0
    st = eng.stats()
    total_new = st["decode_tokens"]
    print(f"served {args.requests} requests / {total_new} tokens in "
          f"{dt:.2f}s ({chunks} chunk cycles, {total_new/dt:.1f} tok/s, "
          f"continuous batching over {args.max_batch} slots)")
    print(f"host syncs/token {st['host_syncs_per_token']:.4f} "
          f"(decode_chunk={args.decode_chunk}), chunk latency "
          f"p50 {st['p50_chunk_ms']:.1f} ms / p95 {st['p95_chunk_ms']:.1f} ms")
    if "spec" in st:
        sp = st["spec"]
        print(f"self-speculation: K={sp['spec_k']}, draft "
              f"{sp['draft_planes']} planes (+{sp['draft_extra_hbm_bytes']} "
              f"bytes weight HBM), {sp['verify_steps']} verify rounds, "
              f"{sp['mean_accepted_per_step']:.2f} draft tokens accepted / "
              f"round ({sp['mean_emitted_per_step']:.2f} emitted)")
    if st["paged"]:
        line = (f"paged pool: {st['num_cache_blocks']} x "
                f"{st['cache_block_size']}-token blocks, cache HBM "
                f"{st['cache_hbm_bytes'] / 1e6:.2f} MB, occupancy "
                f"{st['slot_occupancy']:.2f}, blocked admissions "
                f"{st['admit_blocked']}/{st['admit_attempts']}")
        if "prefix_cache" in st:
            pc = st["prefix_cache"]
            line += (f", prefix hits {pc['hits']} (reused "
                     f"{st['prefill_tokens_reused']} prompt tokens)")
        print(line)
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"trace: {len(tracer)} events -> {args.trace_out} "
              "(open at ui.perfetto.dev)")
    if args.metrics_out is not None:
        if args.metrics_out.endswith(".prom"):
            with open(args.metrics_out, "w") as f:
                f.write(eng.prometheus_text())
        else:
            import json
            with open(args.metrics_out, "w") as f:
                json.dump(eng.metrics_snapshot(), f, indent=2)
        print(f"metrics -> {args.metrics_out}")
    if recorder is not None:
        import json
        with open(args.dispatch_log, "w") as f:
            json.dump(recorder.summary(), f, indent=2)
        s = recorder.summary()
        print(f"dispatch log: {s['decisions']} mpGEMM decisions "
              f"({s['tuned']} tuned, {s['heuristic']} heuristic, "
              f"{s['forced']} forced) -> {args.dispatch_log}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
