"""Serving driver: quantize a model to the packed low-bit format and serve a
batch of requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 12 --max-new 24 --mode lut_xla
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.mpgemm import FUSION_MODES, MPGEMM_MODES
from repro.models import api
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--mode", default="lut_xla",
                    choices=list(MPGEMM_MODES))
    ap.add_argument("--fusion", default="auto",
                    choices=list(FUSION_MODES),
                    help="lut_pallas precompute placement: fused keeps the "
                         "table in VMEM, staged round-trips it through HBM")
    ap.add_argument("--weight-bits", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    cfg = cfg.replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode=args.mode, weight_bits=args.weight_bits,
                         fusion=args.fusion)

    print(f"init + quantize ({args.mode}, W{args.weight_bits}) ...")
    quantized = args.mode != "fp16"
    params = api.init_params(jax.random.key(0), cfg,
                             serve_quantized=quantized)
    if not quantized:
        cfg = cfg.replace(quant=None)

    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    ticks = eng.run_to_completion()
    dt = time.time() - t0
    total_new = args.requests * args.max_new
    print(f"served {args.requests} requests / {total_new} tokens in "
          f"{dt:.2f}s ({ticks} ticks, {total_new/dt:.1f} tok/s, "
          f"continuous batching over {args.max_batch} slots)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
