"""Paper Table 5 analogue: does INT8 table quantization hurt?

No WikiText2/MMLU offline, so fidelity is measured numerically on realistic
distributions (gaussian weights, activations with heavy-tailed outliers as
in real LLMs):
  * mpGEMM output error of W2 + fp32-table vs W2 + int8-table (per_row and
    per_group) against the exact W2 product — isolating the table's
    contribution exactly as Table 5 isolates PPL deltas;
  * end-to-end logits: a reduced LM's output KL divergence fp-table vs
    int8-table on random prompts.

Paper's claim: INT8 tables are ~free (PPL 7.68 -> 7.69).
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import quantize as Q
from repro.kernels import ref
from repro.models import api


def _acts(m, k, outlier_frac=0.01, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k))
    mask = rng.random(a.shape) < outlier_frac
    a = np.where(mask, a * 20.0, a)  # LLM-style channel outliers
    return jnp.asarray(a, jnp.float32)


def mpgemm_fidelity():
    rows = []
    for m, k, n in [(64, 1024, 1024), (8, 4096, 1024)]:
        a = _acts(m, k)
        w = jnp.asarray(np.random.default_rng(1).normal(size=(n, k)), jnp.float32)
        qw = Q.quantize(w, 2, k_group=4)
        exact = ref.ref_lut_mpgemm_matmul(a, qw, table_quant=None)
        scale = float(jnp.mean(jnp.abs(exact)))
        for tq in ("per_row", "per_group"):
            got = ref.ref_lut_mpgemm_matmul(a, qw, table_quant=tq)
            rel = float(jnp.mean(jnp.abs(got - exact))) / scale
            rows.append((f"{m}x{k}x{n}", tq, rel))
    return rows


def e2e_kl():
    cfg = registry.get_reduced("tinyllama-1.1b").replace(
        activation_dtype=jnp.float32)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (4, 32)), jnp.int32)
    outs = {}
    for tq in (None, "per_row", "per_group"):
        c = cfg.with_quant(table_quant=tq) if tq else cfg.with_quant(
            table_quant=None)
        logits, _, _ = api.forward(params, {"tokens": toks}, c)
        outs[tq] = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    kls = {}
    p = jnp.exp(outs[None])
    for tq in ("per_row", "per_group"):
        kls[tq] = float(jnp.mean(jnp.sum(p * (outs[None] - outs[tq]), -1)))
    return kls


def main():
    print("# Table 5 analogue: INT8 table quantization fidelity")
    print("shape,table_quant,mean_rel_err")
    for shape, tq, rel in mpgemm_fidelity():
        print(f"{shape},{tq},{rel:.5f}")
    print("e2e_kl_vs_fp_table (reduced LM, W2):")
    for tq, kl in e2e_kl().items():
        print(f"kl,{tq},{kl:.6f}")


if __name__ == "__main__":
    main()
