"""Paper Table 1 / Fig. 17 analogue: end-to-end inference latency model.

The paper's own end-to-end numbers come from a tile-level roofline
simulator (their Accel-Sim is too slow); ours is the same style of
analytical model, parameterized by v5e constants instead of the A100.
Per layer, per op: latency = max(compute term, HBM term); sum over the
model; prefill (BS1 SEQ2048) and decode (BS1024 SEQ1) like Table 1.

Modes: W16A16 (fp16 TC baseline), W2A16-dequant (stock-hardware mpGEMM),
W2A16-LUT (our TPU LUT: packed weight streaming + int8 MXU lookup GEMM),
ternary-LUT (BitNet b1.58).
"""

from repro.configs import registry
from repro.roofline import hw


def _linear_lat(m, k, n, mode, w_bits):
    a_b = m * k * 2
    o_b = m * n * 2
    if mode == "fp16":
        w_b = k * n * 2
        t_c = 2 * m * n * k / hw.PEAK_BF16_FLOPS
    elif mode == "dequant":
        w_b = k * n * w_bits / 8
        t_c = 2 * m * n * k / hw.PEAK_BF16_FLOPS
    else:  # lut (K_group=2, int8 tables -> int8 MXU rate)
        w_b = k * n * w_bits / 8
        t_c = 2 * m * n * k / hw.PEAK_INT8_OPS
        a_b += m * k  # int8 table (K=2: same element count as A)
    return max(t_c, (a_b + w_b + o_b) / hw.HBM_BW)


def model_latency(cfg, m_tokens, mode, w_bits, kv_len=0, batch=1):
    """Sum of projection latencies + attention terms for one forward."""
    d, hd = cfg.d_model, cfg.head_dim
    qkv_n = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    lat = 0.0
    for _ in range(cfg.n_layers):
        lat += _linear_lat(m_tokens, d, qkv_n, mode, w_bits)
        lat += _linear_lat(m_tokens, cfg.n_heads * hd, d, mode, w_bits)
        lat += 3 * _linear_lat(m_tokens, d, cfg.d_ff, mode, w_bits)
        if kv_len:  # decode attention: stream the KV cache
            kv_b = 2 * batch * kv_len * cfg.n_kv_heads * hd * 2
            lat += kv_b / hw.HBM_BW
    lat += _linear_lat(m_tokens, d, cfg.vocab_size, mode, w_bits)
    return lat


def main():
    print("# Table 1 analogue: e2e latency model on v5e (single chip)")
    print("model,config,mode,latency_ms,speedup_vs_fp16")
    cases = [
        ("paper-bitnet-3b", "BS1_SEQ2048", 2048, 0, 1),
        ("paper-bitnet-3b", "BS1024_SEQ1", 1024, 2048, 1024),
        # noKV isolates the mpGEMM effect (BitNet-3B is MHA: at BS1024 its
        # KV-cache streaming swamps everything on ANY datapath — GQA archs
        # below show the realistic mixed picture)
        ("paper-bitnet-3b", "BS1024_SEQ1_noKV", 1024, 0, 1024),
        ("tinyllama-1.1b", "BS1_SEQ2048", 2048, 0, 1),
        ("tinyllama-1.1b", "BS1024_SEQ1", 1024, 2048, 1024),
        ("tinyllama-1.1b", "BS1_decode", 1, 2048, 1),
        ("llama3.2-3b", "BS1_decode", 1, 2048, 1),
        ("llama3.2-3b", "BS1024_SEQ1", 1024, 2048, 1024),
    ]
    for arch, label, m, kv, batch in cases:
        cfg = registry.get_config(arch)
        base = model_latency(cfg, m, "fp16", 16, kv, batch)
        for mode, bits in [("fp16", 16), ("dequant", 2), ("lut", 2)]:
            lat = model_latency(cfg, m, mode, bits, kv, batch)
            print(f"{arch},{label},{mode},{lat*1e3:.2f},{base/lat:.2f}x")


if __name__ == "__main__":
    main()
