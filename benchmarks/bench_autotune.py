"""Kernel-autotuner benchmark: measured dispatch vs the LMMA heuristic.

For a sweep of decode/prefill-shaped mpGEMM problems this bench runs the
measured-time tuner (``core.autotune``) and reports, per shape:

  * ``heuristic_ms`` — steady-state time of the config ``fusion="auto"``
    would dispatch (always candidate 0 of the tuner's search space);
  * ``tuned_ms`` / ``speedup`` — steady-state of the measured winner. The
    heuristic is itself a candidate, so ``tuned_ms <= heuristic_ms`` within
    one measurement pass — the tuner can only match or beat the prior;
  * per-candidate ``compile_ms`` vs ``steady_ms`` — the split that tells a
    compile-churn problem from a genuinely bad tile (the decode_chunk=16
    post-mortem in docs/KERNEL_TUNING.md is exactly this distinction);
  * cache economics — entries resolved from the persistent cache skip
    measurement entirely; ``hit_selection_ms`` is the trace-time cost of a
    cache-hit dispatch decision (target: well under 1 ms).

Run twice with the same ``--cache`` to see the second run resolve every
shape from disk (``--expect-hits`` turns that into a hard assertion — the
CI smoke job does exactly that):

    PYTHONPATH=src python benchmarks/bench_autotune.py --smoke --cache /tmp/tc.json
    PYTHONPATH=src python benchmarks/bench_autotune.py --smoke --cache /tmp/tc.json \
        --expect-hits
    PYTHONPATH=src python benchmarks/bench_autotune.py --out BENCH_autotune.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.core import autotune
from repro.core.quantize import quantize

# (m, n, k): decode GEMVs (m = max_batch) through prefill-chunk shapes
# (m = chunk length), n/k spanning head-proj to lm-head aspect ratios
SHAPES = [
    (4, 512, 64),     # reduced-tinyllama lm_head at max_batch=4 decode
    (4, 256, 128),    # wide-K projection, decode
    (8, 512, 256),    # decode at max_batch=8
    (16, 512, 64),    # prefill chunk 16 through the lm_head shape
    (64, 1024, 256),  # long prefill chunk, elongated-N regime
]
SMOKE_SHAPES = SHAPES[:2]


def tune_shape(m, n, k, *, bits, k_group, cache, repeats, max_candidates):
    w = jax.random.normal(jax.random.key(n * 31 + k), (n, k))
    qw = quantize(w, bits, k_group=k_group)
    key = autotune.shape_key(m, qw.n, qw.g, qw.k_group, qw.num_planes)

    t0 = time.perf_counter()
    cached = cache.lookup(key)
    sel_ms = (time.perf_counter() - t0) * 1e3
    if cached is not None:
        return {
            "m": m, "n": n, "k": k, "key": key, "cache": "hit",
            "hit_selection_ms": sel_ms,
            "heuristic_ms": cached.heuristic_ms,
            "tuned_ms": cached.steady_ms,
            "speedup": cached.heuristic_ms / max(cached.steady_ms, 1e-9),
            "best": cached.as_dict(),
        }

    t0 = time.perf_counter()
    best, measured = autotune.tune_mpgemm(
        m, qw, cache=cache, repeats=repeats, max_candidates=max_candidates)
    tune_s = time.perf_counter() - t0
    heur = next(c for c in measured if c.source == "heuristic")
    return {
        "m": m, "n": n, "k": k, "key": key, "cache": "miss",
        "tune_s": tune_s,
        "heuristic_ms": heur.steady_ms,
        "tuned_ms": best.steady_ms,
        "speedup": heur.steady_ms / max(best.steady_ms, 1e-9),
        "best": best.as_dict(),
        "candidates": [dataclasses.asdict(c) for c in measured],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", default=".tuning_cache.json",
                    help="persistent tuning cache (JSON) to read/update")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape budget (2 shapes, fewer candidates)")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--k-group", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--max-candidates", type=int, default=6)
    ap.add_argument("--expect-hits", action="store_true",
                    help="fail unless every shape resolves from the cache "
                         "(CI second-run assertion)")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.repeats = min(args.repeats, 2)
        args.max_candidates = min(args.max_candidates, 4)

    shapes = SMOKE_SHAPES if args.smoke else SHAPES
    cache = autotune.TuningCache(args.cache)
    preloaded = len(cache)

    rows = []
    for m, n, k in shapes:
        r = tune_shape(m, n, k, bits=args.bits, k_group=args.k_group,
                       cache=cache, repeats=args.repeats,
                       max_candidates=args.max_candidates)
        rows.append(r)
        if r["cache"] == "hit":
            print(f"[{m:>3}x{n:<5}k{k:<4}] cache HIT   "
                  f"selection {r['hit_selection_ms']:.3f} ms  "
                  f"steady {r['tuned_ms']:.2f} ms "
                  f"({r['best']['fusion']} bm={r['best']['block_m']} "
                  f"bn={r['best']['block_n']} bg={r['best']['block_g']})")
        else:
            print(f"[{m:>3}x{n:<5}k{k:<4}] tuned in {r['tune_s']:.1f}s: "
                  f"heuristic {r['heuristic_ms']:.2f} ms -> "
                  f"tuned {r['tuned_ms']:.2f} ms "
                  f"({r['speedup']:.2f}x, {r['best']['fusion']} "
                  f"bm={r['best']['block_m']} bn={r['best']['block_n']} "
                  f"bg={r['best']['block_g']})")

    cache.save()
    hits = sum(1 for r in rows if r["cache"] == "hit")
    misses = len(rows) - hits
    if args.expect_hits and misses:
        raise SystemExit(f"--expect-hits: {misses} shapes missed the cache "
                         f"{args.cache!r}")

    # second-run simulation: reload the persisted cache cold and time the
    # dispatch-decision lookup for every swept shape (what fusion="tuned"
    # pays at trace time once the cache is warm)
    fresh = autotune.TuningCache(args.cache)
    for r in rows:
        t0 = time.perf_counter()
        hit = fresh.lookup(r["key"])
        r["hit_selection_ms"] = (time.perf_counter() - t0) * 1e3
        r["persisted"] = hit is not None

    result = {
        "bench": "autotune",
        "backend": cache.backend,
        "jax_version": cache.jax_version,
        "bits": args.bits,
        "k_group": args.k_group,
        "repeats": args.repeats,
        "max_candidates": args.max_candidates,
        "cache_path": args.cache,
        "cache_entries_before": preloaded,
        "cache_entries_after": len(cache),
        "cache_hits": hits,
        "cache_misses": misses,
        # raw TuningCache lookup counters (hits/misses/sanitized/foreign) —
        # the same dict engine.stats()["tuning_cache"] exposes
        "cache_counters": cache.counters(),
        "shapes": rows,
    }
    tuned_rows = [r for r in rows if r["cache"] == "miss"]
    if tuned_rows:
        result["min_speedup"] = min(r["speedup"] for r in tuned_rows)
        result["mean_speedup"] = float(np.mean([r["speedup"]
                                                for r in tuned_rows]))
        print(f"tuned >= heuristic on {len(tuned_rows)}/{len(tuned_rows)} "
              f"tuned shapes (min {result['min_speedup']:.2f}x, "
              f"mean {result['mean_speedup']:.2f}x)")
    result["second_run_all_hits"] = all(r["persisted"] for r in rows)
    result["hit_selection_ms_max"] = max(r["hit_selection_ms"] for r in rows)
    print(f"second-run cache hit on {sum(r['persisted'] for r in rows)}"
          f"/{len(rows)} shapes, selection max "
          f"{result['hit_selection_ms_max']:.3f} ms")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
