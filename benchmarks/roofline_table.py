"""Render the §Dry-run / §Roofline tables for EXPERIMENTS.md from the
dryrun result JSONs."""

import json
import os
import sys


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def load(paths):
    rows = []
    for p in paths:
        if os.path.exists(p):
            rows += json.load(open(p))
    return rows


def render(rows, mesh="single"):
    out = []
    out.append("| arch | shape | kind | t_compute (s) | t_memory (s) | "
               "t_collective (s) | dominant | MODEL_FLOPS/HLO | "
               "mem-eff | roofline |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("mesh") != mesh or r.get("mode"):
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        f = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {f['t_compute']:.2e} | {f['t_memory']:.2e} "
            f"| {f['t_collective']:.2e} | {f['dominant']} "
            f"| {f['useful_flops_ratio']:.3f} | {f['memory_efficiency']:.3f} "
            f"| {f['roofline_fraction']:.3f} |")
    return "\n".join(out)


def render_memory(rows, mesh="single"):
    out = ["| arch | shape | args GiB/dev | temp GiB/dev | peak GiB/dev | "
           "compile s |", "|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh or r["status"] != "ok" or r.get("mode"):
            continue
        m = r["memory"]
        out.append(f"| {r['arch']} | {r['shape']} "
                   f"| {fmt_bytes(m['argument_bytes'])} "
                   f"| {fmt_bytes(m['temp_bytes'])} "
                   f"| {fmt_bytes(m['peak_per_device'])} "
                   f"| {r['compile_s']} |")
    return "\n".join(out)


def main(paths=None):
    paths = paths or ["dryrun_single.json", "dryrun_multi.json"]
    rows = load(paths)
    if not rows:
        print("(no dryrun_*.json found — run repro.launch.dryrun first)")
        return
    print("## Roofline (single-pod 16x16 = 256 chips)\n")
    print(render(rows, "single"))
    print("\n## Dry-run memory (single-pod)\n")
    print(render_memory(rows, "single"))
    multi = [r for r in rows if r.get("mesh") == "multi"]
    if multi:
        n_ok = sum(r["status"] == "ok" for r in multi)
        n_skip = sum(r["status"] == "skipped" for r in multi)
        n_err = len(multi) - n_ok - n_skip
        print(f"\n## Multi-pod (2x16x16 = 512 chips): "
              f"{n_ok} ok / {n_skip} skipped / {n_err} errors\n")
        print(render(multi, "multi"))


if __name__ == "__main__":
    main(sys.argv[1:] or None)
