"""Telemetry-overhead benchmark: the observability layer must be ~free.

Runs the SAME serving workload on two warmed engines — telemetry OFF
(no tracer, no dispatch recorder; the metrics registry always exists, it
IS the engine's latency storage) and telemetry ON (request-lifecycle
Tracer + mpGEMM dispatch recording + metrics exposition) — with measured
reps interleaved off/on/off/on so slow machine drift cancels out of the
ratio, and reports:

  * ``decode_tok_s`` best-of-``--repeats`` for each, and the ON/OFF ratio.
    ``--assert-overhead R`` (CI gate: 0.97) exits nonzero if the traced
    engine loses more than ``1 - R`` of decode throughput;
  * ``host_syncs_per_token`` for both — asserted EQUAL unconditionally:
    tracing takes host timestamps only at sync points the engine already
    has, so it can never add a device round-trip (the one-sync-per-chunk
    contract from docs/SERVING.md);
  * the emitted Chrome-trace validated against the format invariants
    (``repro.obs.trace.validate_chrome_trace``) plus event counts, and the
    dispatch-decision summary.

    PYTHONPATH=src python benchmarks/bench_telemetry.py --smoke
    PYTHONPATH=src python benchmarks/bench_telemetry.py \
        --assert-overhead 0.97 --out BENCH_telemetry.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.obs import dispatch as dispatch_obs
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.serving.engine import Request, ServingEngine


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 24)),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def make_engine(cfg, params, args, *, tracer=None):
    """One AOT-compiled, warmed engine (compile + first-touch off the clock)."""
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, decode_chunk=args.decode_chunk,
                        prefill_chunk=args.prefill_chunk,
                        metrics=MetricsRegistry(), tracer=tracer)
    eng._decode.lower(eng.params, eng.state).compile()
    for r in _requests(cfg, args.max_batch, 2, seed=1):
        eng.submit(r)
    eng.run_to_completion()
    return eng


def run_rep(eng, cfg, args):
    """One measured rep of the workload on a warmed engine."""
    eng.reset()
    for r in _requests(cfg, args.requests, args.max_new, seed=0):
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_to_completion()
    wall = time.perf_counter() - t0
    st = eng.stats()
    st["wall_s"] = wall
    return st


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest footprint: fewer requests/tokens/reps")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=192)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--mode", default="lut_xla")
    ap.add_argument("--weight-bits", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5,
                    help="measured reps per side; best decode_tok_s counts")
    ap.add_argument("--assert-overhead", type=float, default=None,
                    metavar="R", help="exit nonzero unless telemetry-on "
                    "decode tok/s >= R x telemetry-off (CI gate: 0.97)")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.max_new = 4, 16
        args.repeats = min(args.repeats, 2)

    cfg = registry.get_reduced(args.arch).replace(
        activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode=args.mode, weight_bits=args.weight_bits)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)

    # warm both engines up front, then INTERLEAVE measured reps (off, on,
    # off, on, ...): the 3% gate is tighter than slow machine drift on a
    # shared box, so pairing reps in time keeps drift out of the ratio.
    dispatch_obs.disable()
    eng_off = make_engine(cfg, params, args, tracer=None)
    tracer = Tracer()
    recorder = dispatch_obs.enable(dispatch_obs.DispatchRecorder())
    eng_on = make_engine(cfg, params, args, tracer=tracer)

    off = on = None
    for _ in range(max(1, args.repeats)):
        dispatch_obs.disable()
        st = run_rep(eng_off, cfg, args)
        if off is None or st["decode_tok_s"] > off["decode_tok_s"]:
            off = st
        dispatch_obs.enable(recorder)
        st = run_rep(eng_on, cfg, args)
        if on is None or st["decode_tok_s"] > on["decode_tok_s"]:
            on = st
    dispatch_obs.disable()
    print(f"telemetry OFF: {off['decode_tok_s']:8.1f} decode tok/s  "
          f"syncs/tok {off['host_syncs_per_token']:.4f}")
    print(f"telemetry ON:  {on['decode_tok_s']:8.1f} decode tok/s  "
          f"syncs/tok {on['host_syncs_per_token']:.4f}  "
          f"({len(tracer)} trace events)")

    # the sync contract is not a threshold: tracing reuses the timestamps
    # the chunk sync already earns, so the counts must match exactly
    if on["host_syncs_per_token"] != off["host_syncs_per_token"]:
        raise AssertionError(
            f"telemetry changed host_syncs_per_token: "
            f"{off['host_syncs_per_token']} -> {on['host_syncs_per_token']}")

    trace = tracer.chrome_trace()["traceEvents"]
    trace_summary = validate_chrome_trace(trace)
    names = {e["name"] for e in trace}
    for want in ("admit", "decode_chunk", "request"):
        if want not in names:
            raise AssertionError(f"trace is missing {want!r} spans: {names}")
    print(f"trace valid: {trace_summary}")

    ratio = on["decode_tok_s"] / off["decode_tok_s"]
    result = {
        "bench": "telemetry",
        "arch": args.arch,
        "mode": args.mode,
        "weight_bits": args.weight_bits,
        "requests": args.requests,
        "max_new": args.max_new,
        "max_batch": args.max_batch,
        "decode_chunk": args.decode_chunk,
        "repeats": args.repeats,
        "off": {k: off[k] for k in ("decode_tok_s", "decode_tokens",
                                    "host_syncs_per_token", "p50_chunk_ms",
                                    "p95_chunk_ms", "wall_s")},
        "on": {k: on[k] for k in ("decode_tok_s", "decode_tokens",
                                  "host_syncs_per_token", "p50_chunk_ms",
                                  "p95_chunk_ms", "wall_s")},
        "decode_tok_s_ratio": ratio,
        "host_syncs_per_token_equal": True,
        "trace": trace_summary,
        "dispatch": {k: v for k, v in recorder.summary().items()
                     if k != "records"},
        "metrics_series": len(eng_on.metrics_snapshot()["metrics"]),
    }
    print(f"telemetry-on/off decode tok/s ratio: {ratio:.3f}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    if args.assert_overhead is not None and ratio < args.assert_overhead:
        print(f"ASSERTION FAILED: telemetry-on decode tok/s ratio "
              f"{ratio:.3f} < {args.assert_overhead}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
