"""Decoding-mode benchmark: greedy vs bit-plane self-speculation vs beam.

Measures the decoding-mode zoo on the serving engine:

  * ``greedy`` — the legacy one-token-per-step scan (baseline tok/s).
  * ``spec``   — self-speculative decoding where the draft model is the top
    ``--draft-planes`` bit-planes of the SAME packed weights (paper
    §3.1.2): zero extra weight HBM, draft forward cost ~ keep/B of the
    target. Reported: mean accepted draft tokens per verify step, effective
    decode tok/s vs greedy, and the bit-exactness of greedy speculation
    (the spec outputs must equal the greedy outputs token-for-token — the
    speedup is free, not a different sampler).
  * ``beam``   — width-W beam search over pool slots. Quality metric: mean
    length-normalized log-prob of the best hypothesis at width W vs width 1
    (width 1 IS the greedy sequence, so the delta is the search win).

Checkpoint: random initialization gives near-uniform logits, so a
plane-sliced draft would agree with its target almost never and the bench
would measure nothing. We therefore synthesize a checkpoint with
trained-model-like argmax margins: the LM head stays float
(``quant skip="lm_head"``) and the embedding of each token ``t`` gets a
push of ``--margin`` mean-embedding-norms along the (normalized) head row
of ``pi(t)`` for a fixed random permutation ``pi``. That plants a dominant
next-token direction per token — exactly the decisive-logit structure a
trained LM has — while everything else (attention, MLPs, packed planes)
stays the real quantized pipeline. The margin knob sweeps draft/target
agreement smoothly (~0.77 at 32, ~1.0 at 128 on the reduced config), so
the acceptance-rate machinery is exercised between the extremes. This is
disclosed emulation: acceptance rates on real checkpoints depend on the
model; the *mechanics* (accept-prefix, rejection fallback, zero-copy
draft) are what the bench certifies.

    PYTHONPATH=src python benchmarks/bench_decoding.py --reduced --smoke
    PYTHONPATH=src python benchmarks/bench_decoding.py --reduced \
        --out BENCH_decoding.json
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.serving.engine import Request, ServingEngine


def assert_finite(obj, path="result"):
    """Recursively assert every numeric field is finite (no NaN/inf in the
    emitted bench JSON — a NaN rate is a bug, not a data point)."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            assert_finite(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            assert_finite(v, f"{path}[{i}]")
    elif isinstance(obj, bool) or obj is None or isinstance(obj, str):
        pass
    elif isinstance(obj, (int, float)):
        if not math.isfinite(obj):
            raise AssertionError(f"non-finite bench field {path} = {obj}")


def margin_checkpoint(cfg, margin: float, seed: int = 0):
    """Random-init params + planted argmax margins (see module doc).

    Requires ``quant["skip"]`` to keep the LM head float, so the draft and
    target share the head bit-for-bit and the margin survives plane
    slicing of the interior layers.
    """
    params = api.init_params(jax.random.key(seed), cfg, serve_quantized=True)
    head = params["lm_head"]["w"]            # [D, V] float (skip="lm_head")
    rows = head.T                            # [V, D]
    rows_n = rows / (jnp.linalg.norm(rows, axis=1, keepdims=True) + 1e-9)
    emb = params["embed"]["table"]           # [V, D]
    enorm = float(jnp.mean(jnp.linalg.norm(emb, axis=1)))
    pi = jnp.asarray(np.random.default_rng(7).permutation(cfg.vocab_size))
    params["embed"]["table"] = emb + margin * enorm * rows_n[pi]
    return params


def _requests(cfg, n, max_new, *, decoding="greedy", seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 16)),
                                        dtype=np.int32),
                    max_new_tokens=max_new, decoding=decoding)
            for i in range(n)]


def _run(cfg, params, reqs_fn, *, repeats, engine_kw):
    """Warmed engine, best-of-repeats measured run. Returns (stats, reqs)."""
    eng = ServingEngine(cfg, params, **engine_kw)
    for r in reqs_fn():  # warmup: compile every program this workload needs
        eng.submit(r)
    eng.run_to_completion()
    best = best_reqs = None
    for _ in range(max(1, repeats)):
        eng.reset()
        reqs = reqs_fn()
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        st = eng.stats()
        if best is None or st["decode_tok_s"] > best["decode_tok_s"]:
            best, best_reqs = st, reqs
    return best, best_reqs


def bench_spec(cfg, params, args, greedy_out):
    """Self-speculation vs greedy: acceptance rate, effective tok/s,
    zero-copy draft, bit-exact greedy outputs."""
    mk = lambda d: (lambda: _requests(cfg, args.requests, args.max_new,
                                      decoding=d, seed=0))
    kw = dict(max_batch=args.max_batch, max_seq=args.max_seq,
              decode_chunk=args.decode_chunk,
              prefill_chunk=args.prefill_chunk)
    g_st, g_reqs = _run(cfg, params, mk("greedy"), repeats=args.repeats,
                        engine_kw=kw)
    s_st, s_reqs = _run(
        cfg, params, mk(f"spec:draft{args.draft_planes}b"),
        repeats=args.repeats,
        engine_kw=dict(kw, spec_k=args.spec_k,
                       spec_draft_planes=args.draft_planes))
    exact = [a.output == b.output for a, b in zip(g_reqs, s_reqs)]
    sp = s_st["spec"]
    out = {
        "spec_k": args.spec_k,
        "draft_planes": args.draft_planes,
        "draft_extra_hbm_bytes": sp["draft_extra_hbm_bytes"],
        "verify_steps": sp["verify_steps"],
        "accepted_draft_tokens": sp["accepted_draft_tokens"],
        "mean_accepted_per_step": sp["mean_accepted_per_step"],
        "mean_emitted_per_step": sp["mean_emitted_per_step"],
        "greedy_decode_tok_s": g_st["decode_tok_s"],
        "spec_decode_tok_s": s_st["decode_tok_s"],
        "effective_speedup": s_st["decode_tok_s"]
                             / max(1e-9, g_st["decode_tok_s"]),
        "greedy_bit_exact": all(exact),
        "requests_bit_exact": sum(exact),
    }
    print(f"spec (K={args.spec_k}, draft {args.draft_planes} planes, "
          f"+{out['draft_extra_hbm_bytes']} B weight HBM): "
          f"{out['mean_accepted_per_step']:.2f} draft tokens accepted / "
          f"verify step ({out['mean_emitted_per_step']:.2f} emitted), "
          f"{out['spec_decode_tok_s']:.1f} tok/s vs greedy "
          f"{out['greedy_decode_tok_s']:.1f} -> "
          f"{out['effective_speedup']:.2f}x effective "
          f"(bit-exact: {out['requests_bit_exact']}/{len(exact)})")
    return out, g_st


def bench_beam(cfg, params, args):
    """Beam width W vs width 1 (== greedy) on the same prompts: the mean
    best length-normalized log-prob delta is the search quality win."""
    n_req = max(2, args.requests // 2)
    kw = dict(max_batch=max(args.max_batch, args.beam_width),
              max_seq=args.max_seq, decode_chunk=args.decode_chunk,
              prefill_chunk=args.prefill_chunk)
    out = {"beam_width": args.beam_width}
    scores = {}
    for label, w in (("w1", 1), (f"w{args.beam_width}", args.beam_width)):
        mk = lambda: _requests(cfg, n_req, args.max_new,
                               decoding=f"beam:{w}", seed=3)
        st, reqs = _run(cfg, params, mk, repeats=1, engine_kw=kw)
        best_scores = [r.beams[0][1] for r in reqs if r.beams]
        scores[label] = best_scores
        out[label] = {
            "decode_tok_s": st["decode_tok_s"],
            "mean_best_score": float(np.mean(best_scores)),
        }
    out["quality_delta"] = (out[f"w{args.beam_width}"]["mean_best_score"]
                            - out["w1"]["mean_best_score"])
    out["never_worse"] = bool(all(
        b >= a - 1e-6 for a, b in zip(scores["w1"],
                                      scores[f"w{args.beam_width}"])))
    print(f"beam: width {args.beam_width} mean best score "
          f"{out[f'w{args.beam_width}']['mean_best_score']:.3f} vs width 1 "
          f"(greedy) {out['w1']['mean_best_score']:.3f} -> "
          f"+{out['quality_delta']:.3f} log-prob "
          f"(never worse per request: {out['never_worse']})")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced smoke dims (default; --full overrides)")
    ap.add_argument("--full", action="store_true",
                    help="published config dims")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest footprint: fewer requests/tokens")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=192,
                    help="long decode runs so the fixed-length chunk scan's "
                         "tail waste (slots that finish mid-chunk idle to "
                         "the chunk boundary) stays small relative to the "
                         "measured steady state")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--decode-chunk", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--weight-bits", type=int, default=4,
                    help="packed width of the TARGET (draft slices it)")
    ap.add_argument("--draft-planes", type=int, default=1,
                    help="bit-planes kept in the self-speculation draft. "
                         "The XLA-CPU emulation's per-forward cost is "
                         "plane-proportional (the packed->CW expansion "
                         "runs every step under store='packed'), so fewer "
                         "draft planes buy a cheaper rollout; the margin "
                         "checkpoint keeps even the 1-plane draft's "
                         "agreement high. Serving quality-sensitive "
                         "sampling workloads favours 2")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per verify round")
    ap.add_argument("--beam-width", type=int, default=4)
    ap.add_argument("--margin", type=float, default=96.0,
                    help="planted argmax margin in mean-embedding-norm "
                         "units (see module doc); sweeps draft agreement")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--assert-spec-speedup", type=float, default=None,
                    metavar="R", help="exit nonzero unless spec effective "
                                      "tok/s >= R x greedy")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.max_new, args.repeats = 4, 24, 1

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_reduced(args.arch))
    cfg = cfg.replace(activation_dtype=jnp.float32)
    # packed store pinned: the draft view is a plane slice of the packed
    # buffers (the CPU CW-expansion hoist would destroy sliceability);
    # float LM head so draft and target share the readout exactly
    cfg = cfg.with_quant(mpgemm_mode="lut_xla",
                         weight_bits=args.weight_bits,
                         store="packed", skip="lm_head")

    print(f"margin checkpoint (margin={args.margin}, "
          f"W{args.weight_bits} packed, float head) ...")
    t0 = time.time()
    params = margin_checkpoint(cfg, args.margin)
    print(f"  built in {time.time() - t0:.1f}s")

    result = {
        "bench": "decoding",
        "arch": args.arch,
        "reduced": not args.full,
        "weight_bits": args.weight_bits,
        "margin": args.margin,
        "max_batch": args.max_batch,
        "max_seq": args.max_seq,
        "requests": args.requests,
        "max_new": args.max_new,
        "decode_chunk": args.decode_chunk,
    }
    result["spec"], greedy_st = bench_spec(cfg, params, args, None)
    result["beam"] = bench_beam(cfg, params, args)

    failed = []
    if not result["spec"]["greedy_bit_exact"]:
        failed.append("greedy self-speculation is not bit-exact with greedy")
    if result["spec"]["draft_extra_hbm_bytes"] != 0:
        failed.append(f"draft view costs "
                      f"{result['spec']['draft_extra_hbm_bytes']} extra "
                      "weight bytes (expected 0)")
    if args.assert_spec_speedup is not None:
        r = result["spec"]["effective_speedup"]
        if r < args.assert_spec_speedup:
            failed.append(f"spec effective speedup {r:.3f} < "
                          f"{args.assert_spec_speedup}")
        acc = result["spec"]["mean_accepted_per_step"]
        if acc < 2.0:
            failed.append(f"mean accepted draft tokens/step {acc:.2f} < 2")
    assert_finite(result)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    if failed:
        print("ASSERTION FAILED: " + "; ".join(failed))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
