"""Paper Fig. 4 / Fig. 18 analogue: mpGEMM kernel comparison.

Compares on LLAMA2-70B-derived shapes (scaled to CPU feasibility):
  * fp16 GEMM                  (cuBLAS analogue — the reference)
  * dequant mpGEMM             (CUTLASS dequant analogue, paper baseline)
  * LUT software, gather form  (LUT-GEMM analogue — the literal per-group
                                lookup; the paper's Fig 4 shows this LOSES
                                on stock hardware at batch>1)
  * LUT T@CW int8 form         (LUT Tensor Core analogue — the co-designed
                                datapath, here as the one-GEMM formulation)

Reports CPU µs/call plus the analytic v5e roofline projection per shape
(which is the number that transfers to the target hardware).
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.mpgemm import mpgemm
from repro.kernels import ref
from repro.roofline import hw

# (name, M, N, K): GEMV (M=1) and GEMM (large M) cases, LLAMA2-70B ratios
SHAPES = [
    ("M0_gemv", 1, 2048, 2048),
    ("M1_small", 16, 2048, 2048),
    ("M2_gemm", 256, 2048, 2048),
    ("M3_wide", 64, 5632, 2048),
]


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def v5e_projection(m, n, k, mode, w_bits=2):
    """Analytic per-shape latency on v5e (roofline max of terms)."""
    a_bytes = m * k * 2
    out_bytes = m * n * 4
    if mode == "fp16":
        w_bytes = n * k * 2
        t_c = 2 * m * n * k / hw.PEAK_BF16_FLOPS
    elif mode == "dequant":
        w_bytes = n * k * w_bits / 8
        t_c = 2 * m * n * k / hw.PEAK_BF16_FLOPS  # bf16 MXU after upcast
    else:  # lut (K_group=2 int8 path)
        w_bytes = n * k * w_bits / 8
        t_c = 2 * m * n * k / hw.PEAK_INT8_OPS  # int8 MXU on T@CW
    t_m = (a_bytes + w_bytes + out_bytes) / hw.HBM_BW
    return max(t_c, t_m) * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    for name, m, n, k in SHAPES:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        wf = jnp.asarray(w.T)
        qw2 = Q.quantize(w, 2, k_group=2, scheme="symmetric")
        qw4 = Q.quantize(w, 2, k_group=4, scheme="symmetric")

        f_fp16 = jax.jit(lambda a, w: a @ w)
        f_deq = jax.jit(lambda a, qw=qw2: mpgemm(a, qw, mode="dequant"))
        f_gather = jax.jit(lambda a, qw=qw4: ref.ref_lut_mpgemm_gather(a, qw))
        f_lut = jax.jit(lambda a, qw=qw2: mpgemm(a, qw, mode="lut_xla",
                                                 table_quant="per_row"))
        t_fp16 = _time(f_fp16, a, wf)
        t_deq = _time(f_deq, a)
        t_gather = _time(f_gather, a) if m <= 64 else float("nan")
        t_lut = _time(f_lut, a)
        rows.append({
            "shape": name, "m": m, "n": n, "k": k,
            "cpu_us": {"fp16": t_fp16, "dequant": t_deq,
                       "lut_gather_sw": t_gather, "lut_tc": t_lut},
            "v5e_us": {md: v5e_projection(m, n, k, md)
                       for md in ("fp16", "dequant", "lut")},
        })
    return rows


def main():
    print("# Fig4/18 analogue: mpGEMM kernels (CPU measured + v5e projected)")
    print("shape,mode,cpu_us,v5e_us,v5e_speedup_vs_fp16")
    for r in run():
        base = r["v5e_us"]["fp16"]
        for mode in ("fp16", "dequant", "lut_gather_sw", "lut_tc"):
            v5e = r["v5e_us"].get(
                {"lut_tc": "lut", "lut_gather_sw": "lut"}.get(mode, mode))
            print(f"{r['shape']},{mode},{r['cpu_us'][mode]:.0f},"
                  f"{v5e:.2f},{base / v5e:.2f}")


if __name__ == "__main__":
    main()
