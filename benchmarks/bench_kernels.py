"""Kernel-level microbench (paper §4.3 analogue at interpret-mode scale):
Pallas LUT kernel vs Pallas dequant kernel vs jnp reference, small shapes
(interpret mode executes the kernel body in Python — timings are for
relative sanity on CPU; the TPU projection comes from bench_mpgemm)."""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.kernels import ops, ref


def _time(fn, reps=2):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    rng = np.random.default_rng(0)
    m, k, n = 16, 256, 256
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    qw = Q.quantize(w, 2, k_group=4)
    print("# kernel-level (interpret mode, correctness-bearing timings only)")
    print("kernel,us_per_call,max_abs_err_vs_oracle")
    want = np.asarray(ref.ref_lut_mpgemm_matmul(a, qw, table_quant="per_row"))
    got = np.asarray(ops.lut_mpgemm(a, qw, table_quant="per_row",
                                    block_m=8, block_n=128, block_g=8,
                                    interpret=True))
    t = _time(lambda: ops.lut_mpgemm(a, qw, table_quant="per_row", block_m=8,
                                     block_n=128, block_g=8, interpret=True))
    print(f"lut_mpgemm_pallas,{t:.0f},{np.abs(got - want).max():.2e}")
    wantd = np.asarray(ref.ref_dequant_mpgemm(a, qw))
    gotd = np.asarray(ops.dequant_mpgemm(a, qw, block_m=8, block_n=128,
                                         block_g=8, interpret=True))
    t = _time(lambda: ops.dequant_mpgemm(a, qw, block_m=8, block_n=128,
                                         block_g=8, interpret=True))
    print(f"dequant_mpgemm_pallas,{t:.0f},{np.abs(gotd - wantd).max():.2e}")
    tt = ops.table_precompute(a, 4, "per_row", block_m=8, block_g=8,
                              interpret=True)
    wt = ref.ref_table_precompute(a, 4, "per_row")
    t = _time(lambda: ops.table_precompute(a, 4, "per_row", block_m=8,
                                           block_g=8, interpret=True).values)
    err = np.abs(np.asarray(tt.values, np.int32)
                 - np.asarray(wt.values, np.int32)).max()
    print(f"table_precompute_pallas,{t:.0f},{err:.2e}")


if __name__ == "__main__":
    main()
