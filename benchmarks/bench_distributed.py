"""Tensor-parallel sharded decode benchmark: single-device dense vs a
(data x model) mesh over forced host devices.

The tentpole claim this bench pins down is STRUCTURAL, not wall-clock: on
``--xla_force_host_platform_device_count`` devices every "device" is a slice
of the same CPU, so sharded tok/s can never beat one device and the ideal
linear-scaling bound (dense tok/s x model-parallel degree) is unreachable
by construction. What the bench verifies and records:

  * the compiled sharded decode program really communicates like a
    tensor-parallel decoder — its scanned layer body carries the
    all-reduce (psum) that completes each row-parallel projection and the
    all-gathers GSPMD inserts around the column-parallel ones (collective
    counts are read from the compiled HLO; ops inside the layer scan
    execute once PER LAYER per decode step);
  * the engine still emits every requested token under the plan (parity);
  * measured sharded tok/s, dense tok/s, and the honest ratio against the
    ideal-scaling bound ``dense * mp`` — on real accelerators the gap is
    interconnect overhead; on forced host devices it also contains the
    core-slicing penalty, which is why the JSON states the bound rather
    than asserting against it.

Each scenario runs in a subprocess so the device count is set before jax
initializes. ``make bench-distributed`` writes ``BENCH_distributed.json``.

    PYTHONPATH=src python benchmarks/bench_distributed.py --smoke
    PYTHONPATH=src python benchmarks/bench_distributed.py \
        --mesh 2x4 --out BENCH_distributed.json
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "collective-permute", "all-to-all")


def _child(args) -> int:
    """Runs inside the subprocess: build the engine (sharded or dense),
    compile the decode program, count collectives, serve, report JSON."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import registry
    from repro.models import api
    from repro.serving.engine import Request, ServingEngine

    cfg = registry.get_reduced(args.arch).replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode=args.mode, weight_bits=args.weight_bits)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)

    plan = None
    d = m = 1
    if args.mesh != "1x1":
        from repro.launch.mesh import make_plan, make_serving_mesh
        d, m = (int(v) for v in args.mesh.split("x"))
        plan = make_plan(make_serving_mesh(data=d, model=m), fsdp=False)

    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, decode_chunk=args.decode_chunk,
                        prefill_chunk=args.prefill_chunk, plan=plan)

    compiled = eng._decode.lower(eng.params, eng.state).compile()
    hlo = compiled.as_text()
    counts = {}
    for op in COLLECTIVE_OPS:
        # HLO instruction names: "all-reduce", "all-reduce-start", ...
        counts[op] = len(re.findall(rf"= \S+ {op}(?:-start)?\(", hlo))

    def workload(seed=0):
        rng = np.random.default_rng(seed)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            int(rng.integers(4, 24)),
                                            dtype=np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]

    # warmup (compiles prefill/merge paths), then measured reps
    for r in workload(seed=1):
        eng.submit(r)
    eng.run_to_completion()
    best = None
    for _ in range(max(1, args.repeats)):
        eng.reset()
        reqs = workload(seed=0)
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_to_completion()
        wall = time.perf_counter() - t0
        st = eng.stats()
        assert all(r.done and len(r.output) == args.max_new for r in reqs), \
            "sharded decode dropped tokens"
        rec = {"tok_s": st["decode_tokens"] / wall,
               "decode_tok_s": st["decode_tok_s"],
               "decode_tokens": st["decode_tokens"],
               "host_syncs_per_token": st["host_syncs_per_token"],
               "p50_chunk_ms": st["p50_chunk_ms"],
               "wall_s": wall}
        if best is None or rec["decode_tok_s"] > best["decode_tok_s"]:
            best = rec

    best.update({
        "mesh": {"data": d, "model": m},
        "devices": jax.device_count(),
        "collectives": counts,
        "collectives_total": sum(counts.values()),
        # collectives sit inside the scanned layer body: static count x
        # n_layers executions per decode step
        "n_layers": cfg.n_layers,
    })
    print("BENCH_JSON:" + json.dumps(best))
    return 0


def _run_scenario(args, mesh: str, devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # forced host devices exist only on the CPU backend; pinning it
    # also skips the accelerator-plugin probe (a sleep-poll loop that
    # starves 1-cpu boxes)
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, os.path.abspath(__file__), "--_child",
           "--mesh", mesh, "--arch", args.arch, "--mode", args.mode,
           "--weight-bits", str(args.weight_bits),
           "--requests", str(args.requests), "--max-new", str(args.max_new),
           "--max-batch", str(args.max_batch), "--max-seq", str(args.max_seq),
           "--decode-chunk", str(args.decode_chunk),
           "--prefill-chunk", str(args.prefill_chunk),
           "--repeats", str(args.repeats)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"scenario {mesh} failed:\n{r.stdout}\n"
                           f"{r.stderr[-4000:]}")
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("BENCH_JSON:"))
    return json.loads(line[len("BENCH_JSON:"):])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b",
                    help="reduced config to serve (default: the qwen2-72b "
                         "class the TP plan targets)")
    ap.add_argument("--mesh", default="2x4", metavar="DXM",
                    help="sharded scenario's data x model mesh")
    ap.add_argument("--mode", default="lut_xla")
    ap.add_argument("--weight-bits", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest footprint: fewer requests/tokens")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--_child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.max_new, args.repeats = 4, 8, 1
    if args._child:
        return _child(args)

    d, m = (int(v) for v in args.mesh.split("x"))
    print(f"dense baseline (1 device) ...")
    dense = _run_scenario(args, "1x1", 1)
    print(f"  {dense['decode_tok_s']:.1f} tok/s decode-only, "
          f"collectives {dense['collectives_total']}")
    print(f"sharded {args.mesh} ({d * m} forced host devices) ...")
    shard = _run_scenario(args, args.mesh, d * m)
    cc = shard["collectives"]
    print(f"  {shard['decode_tok_s']:.1f} tok/s decode-only; compiled "
          f"decode HLO: {cc.get('all-reduce', 0)} all-reduce, "
          f"{cc.get('all-gather', 0)} all-gather (inside the layer scan -> "
          f"executed per layer per step)")

    ideal = dense["decode_tok_s"] * m
    result = {
        "bench": "distributed",
        "arch": args.arch,
        "mesh": shard["mesh"],
        "weight_bits": args.weight_bits,
        "mode": args.mode,
        "dense": dense,
        "sharded": shard,
        # one psum (all-reduce) per row-parallel projection per layer is
        # the canonical TP comm structure; the static HLO count sits inside
        # the scanned layer body, so >=1 all-reduce in the decode program
        # means >=1 psum per LAYER at runtime
        "has_per_layer_psum": cc.get("all-reduce", 0) >= 1,
        "ideal_scaling_tok_s": ideal,
        "fraction_of_ideal": shard["decode_tok_s"] / ideal,
        "fraction_of_dense": shard["decode_tok_s"] / dense["decode_tok_s"],
        "note": ("forced host devices time-slice one CPU: fraction_of_ideal "
                 "bounds from below what a real mp-device system would see; "
                 "the structural claims (collectives, parity) are "
                 "device-count faithful"),
    }
    print(f"ideal-scaling bound {ideal:.1f} tok/s (dense x {m}); sharded "
          f"reaches {result['fraction_of_ideal']:.2f} of ideal "
          f"({result['fraction_of_dense']:.2f} of dense) on time-sliced "
          f"host devices")
    if not result["has_per_layer_psum"]:
        print("ASSERTION FAILED: no all-reduce in the sharded decode HLO — "
              "the plan is not producing tensor-parallel computation")
        return 1
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
