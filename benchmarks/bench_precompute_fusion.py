"""Paper Table 4 analogue: table-precompute placement.

The paper's "conventional" inefficiency is *cross-kernel* redundancy: every
LUT kernel (gate, up, down...) precomputes the same table because each GPU
kernel owns its precompute unit. The XLA analogue of a kernel boundary is a
separate jit program, so the three variants are:

  a) unfused:  gate/up/down each a separate jit with INTERNAL precompute
               (3 redundant table builds + 3x table traffic);
  b) split:    the DFG transformation — precompute is its own jit program,
               its output feeds the (lookup-only) consumers;
  c) fused:    split + the precompute composed into one jit with the
               preceding RMSNorm and both gate/up consumers (operator
               fusion, zero extra table traffic).

Interesting XLA-specific finding (recorded in EXPERIMENTS.md): *within* a
single jit scope, CSE already dedups identical precomputes — the DFG
transform matters exactly at program/kernel boundaries, which is where the
paper applies it.

Reports CPU wall time + summed HLO bytes. Paper Table 4: unfused adds
16-24% e2e, fused ~2.5%.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.mpgemm import mpgemm, precompute_tables

D, F, M = 1024, 2816, 256
KG = 4


def _mk():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    g = jnp.ones((D,), jnp.float32)
    qg = Q.quantize(jnp.asarray(rng.normal(size=(F, D)), jnp.float32), 2, KG)
    qu = Q.quantize(jnp.asarray(rng.normal(size=(F, D)), jnp.float32), 2, KG)
    qd = Q.quantize(jnp.asarray(rng.normal(size=(D, F)), jnp.float32), 2, KG)
    return x, g, qg, qu, qd


def _rms(x, g):
    return x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + 1e-5) * g


def _cost(jfn, *args):
    c = jfn.lower(*args).compile().cost_analysis()
    return float(c.get("bytes accessed", 0)), float(c.get("flops", 0))


def main():
    x, g, qg, qu, qd = _mk()

    # separate "kernels" (jit programs)
    j_norm = jax.jit(_rms)
    j_pre = jax.jit(lambda h: precompute_tables(h, KG))
    j_gate_int = jax.jit(lambda h: mpgemm(h, qg, mode="lut_xla"))
    j_up_int = jax.jit(lambda h: mpgemm(h, qu, mode="lut_xla"))
    j_gate_t = jax.jit(lambda h, t: mpgemm(h, qg, mode="lut_xla", table=t))
    j_up_t = jax.jit(lambda h, t: mpgemm(h, qu, mode="lut_xla", table=t))
    j_act = jax.jit(lambda a, b: jax.nn.silu(a) * b)
    j_down_int = jax.jit(lambda hh: mpgemm(hh, qd, mode="lut_xla"))

    def unfused():
        h = j_norm(x, g)
        hh = j_act(j_gate_int(h), j_up_int(h))
        return j_down_int(hh)

    def split():
        h = j_norm(x, g)
        t = j_pre(h)
        hh = j_act(j_gate_t(h, t), j_up_t(h, t))
        return j_down_int(hh)

    j_fused = jax.jit(lambda x, g: (lambda h, t: j_act(
        mpgemm(h, qg, mode="lut_xla", table=t),
        mpgemm(h, qu, mode="lut_xla", table=t)))(
            _rms(x, g), precompute_tables(_rms(x, g), KG)))

    def fused():
        return j_down_int(j_fused(x, g))

    def t_of(fn, reps=5):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / reps * 1e6

    h = j_norm(x, g)
    by_pre, fl_pre = _cost(j_pre, h)
    print("# Table 4 analogue: precompute placement across kernel boundaries")
    print("variant,cpu_us,precompute_builds,precompute_bytes,overhead_vs_fused")
    rows = [("unfused_per_consumer", t_of(unfused), 3, 3 * by_pre),
            ("dfg_split_shared", t_of(split), 1, by_pre),
            ("dfg_split_plus_fusion", t_of(fused), 1, 0.0)]
    base = rows[-1][1]
    for name, us, builds, pb in rows:
        print(f"{name},{us:.0f},{builds},{pb:.3e},{(us - base) / base * 100:+.1f}%")


if __name__ == "__main__":
    main()
