"""Fused vs. staged vs. dequant mpGEMM: HBM traffic model + roofline + timing.

The fused kernel's whole value proposition (§3.1.1) is a traffic trade:

  * staged  — ``table_precompute_pallas`` writes the [M, G·E] table to HBM,
              ``lut_mpgemm_pallas`` reads it back once per N-tile pass
              (grid (i,j,k): the (i,k) table block is re-fetched for every j);
  * fused   — the table is rebuilt on the MXU in-VMEM from the activation
              block; activations are re-read once per N-tile pass instead,
              which is E/k_group-times (f32: 2·E/k_group-times) fewer bytes;
              **table HBM bytes ≡ 0**;
  * dequant — the stock-hardware baseline: same packed-weight traffic, dense
              bf16 MXU after in-core upcast, no table at all.

Run over the config registry's model projection shapes::

    PYTHONPATH=src python benchmarks/bench_fused_mpgemm.py            # analytic
    PYTHONPATH=src python benchmarks/bench_fused_mpgemm.py --run      # + timing
    PYTHONPATH=src python benchmarks/bench_fused_mpgemm.py --smoke    # CI quick

The analytic section is exact arithmetic on the kernels' actual BlockSpecs
(via ops.pick_blocks), so the reported bytes are what the grids really move;
``--run`` adds interpret-mode wall-clock parity/latency on a tiny shape.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import quantize as Q
from repro.core.lmma import LMMADescriptor, select_fusion
from repro.kernels import ops
from repro.roofline import hw

KG = 4
BITS = 2
TABLE_BYTES_PER_ENTRY = 1  # int8 table (per_row quantization, §3.1.3)
ACT_BYTES = 4              # f32 activations on the CPU/test path


def _arch_shapes(arch_id, batches=(1, 256)):
    """(name, M, N, K) projection shapes for one registry arch."""
    cfg = registry.get_config(arch_id)
    d_ff = cfg.d_ff or cfg.dense_d_ff or cfg.d_inner or 2 * cfg.d_model
    for m in batches:
        yield (f"{arch_id}_up_M{m}", m, d_ff, cfg.d_model)
        yield (f"{arch_id}_down_M{m}", m, cfg.d_model, d_ff)


def traffic_model(m, n, k, *, kg=KG, bits=BITS,
                  table_entry_bytes=TABLE_BYTES_PER_ENTRY):
    """Per-call HBM bytes for each pipeline, from the kernels' real grids.

    Grid is (M/bm, N/bn, G/bg) with K innermost: an input block indexed
    (i, k) is fetched N/bn times, one indexed (j, k) is fetched M/bm times.
    Returns dict of dicts with per-stream bytes; the acceptance invariant is
    ``fused["table"] == 0``.
    """
    g = k // kg
    e = 1 << (kg - 1)
    bm, bn, bg = ops.pick_blocks(m, n, g, kg, bits)
    bm, bn, bg = min(bm, max(8, m)), min(bn, n), min(bg, g)
    n_tiles = -(-n // bn)
    m_tiles = -(-m // bm)
    a_bytes = m * k * ACT_BYTES
    table_bytes = m * g * e * table_entry_bytes
    packed_bytes = n * g * bits * kg // 8
    out_bytes = m * n * 4

    staged = {
        "act": a_bytes,                              # precompute reads A once
        "table": table_bytes * (1 + n_tiles),        # write + per-N-tile read
        "weights": packed_bytes * m_tiles,
        "out": out_bytes,
    }
    fused = {
        "act": a_bytes * n_tiles,                    # A re-read per N-tile
        "table": 0,                                  # never leaves VMEM
        "weights": packed_bytes * m_tiles,
        "out": out_bytes,
    }
    dequant = {
        "act": a_bytes * n_tiles,
        "table": 0,
        "weights": packed_bytes * m_tiles,
        "out": out_bytes,
    }
    for d in (staged, fused, dequant):
        d["total"] = d["act"] + d["table"] + d["weights"] + d["out"]
    return {"staged": staged, "fused": fused, "dequant": dequant,
            "blocks": (bm, bn, bg)}


def roofline_us(m, n, k, pipeline, *, kg=KG, bits=BITS):
    """max(compute, memory) latency projection on v5e, µs."""
    g = k // kg
    e = 1 << (kg - 1)
    tr = traffic_model(m, n, k, kg=kg, bits=bits)
    n_tiles = -(-n // tr["blocks"][1])
    lookup_ops = 2 * m * n * g * e                      # T @ CW
    precompute_ops = 2 * m * g * e * kg                 # A-block × sign basis
    if pipeline == "staged":
        t_c = (lookup_ops / hw.PEAK_INT8_OPS
               + precompute_ops / hw.PEAK_BF16_FLOPS)
    elif pipeline == "fused":                           # recompute per N-tile
        t_c = (lookup_ops / hw.PEAK_INT8_OPS
               + n_tiles * precompute_ops / hw.PEAK_BF16_FLOPS)
    else:                                               # dequant: bf16 dense
        t_c = 2 * m * n * k / hw.PEAK_BF16_FLOPS
    t_m = tr[pipeline]["total"] / hw.HBM_BW
    return max(t_c, t_m) * 1e6


def _fmt_bytes(b):
    return f"{b / 2**20:8.2f} MiB" if b else "   0       "


def run_analytic(archs, table_entry_bytes=TABLE_BYTES_PER_ENTRY):
    hdr = (f"{'shape':34s} {'blocks':>14s} {'pipe':>8s} {'table-HBM':>12s} "
           f"{'total-HBM':>12s} {'roofline':>10s}  fusion")
    print(hdr)
    print("-" * len(hdr))
    for arch in archs:
        for name, m, n, k in _arch_shapes(arch):
            tr = traffic_model(m, n, k, table_entry_bytes=table_entry_bytes)
            desc = LMMADescriptor(m=m, n=n, k=k, w_bits=BITS, k_group=KG)
            fusion = select_fusion(desc)
            for pipe in ("staged", "fused", "dequant"):
                us = roofline_us(m, n, k, pipe)
                tag = f"auto→{fusion}" if pipe == "fused" else ""
                print(f"{name:34s} {str(tr['blocks']):>14s} {pipe:>8s} "
                      f"{_fmt_bytes(tr[pipe]['table'])} "
                      f"{_fmt_bytes(tr[pipe]['total'])} {us:9.1f}µs  {tag}")
            assert tr["fused"]["table"] == 0, "fused table traffic must be 0"
        print()


def run_timed(m=16, n=256, k=128):
    """Interpret-mode wall clock (CPU): parity + relative cost only."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    qw = Q.quantize(w, BITS, k_group=KG)
    runs = {
        "fused": lambda: ops.fused_lut_mpgemm(
            a, qw, table_quant="per_row", block_m=8, block_n=128, block_g=8,
            interpret=True),
        "staged": lambda: ops.lut_mpgemm(
            a, qw, table_quant="per_row", fusion="staged", block_m=8,
            block_n=128, block_g=8, interpret=True),
        "dequant": lambda: ops.dequant_mpgemm(
            a, qw, block_m=8, block_n=128, block_g=8, interpret=True),
    }
    outs = {}
    for name, fn in runs.items():
        fn()  # warm
        t0 = time.perf_counter()
        outs[name] = jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) * 1e3
        print(f"{name:>8s}: {dt:8.1f} ms/call (interpret mode, "
              f"M={m} N={n} K={k})")
    err = float(jnp.max(jnp.abs(outs["fused"] - outs["staged"])))
    print(f"max |fused - staged| = {err:.3e}")
    assert err == 0.0, "per_row fused path must be bit-exact with staged"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=None,
                    help="registry arch ids (default: a representative trio)")
    ap.add_argument("--float-table", action="store_true",
                    help="model f32 tables (table_quant=None) instead of "
                         "int8 — the staged pipeline's worst case")
    ap.add_argument("--run", action="store_true",
                    help="also time interpret-mode kernels on a tiny shape")
    ap.add_argument("--smoke", action="store_true",
                    help="one arch, analytic only (CI)")
    args = ap.parse_args(argv)
    if args.smoke:
        archs = ["tinyllama-1.1b"]
    elif args.archs:
        archs = args.archs
    else:
        archs = ["tinyllama-1.1b", "paper-bitnet-3b", "qwen2-72b"]
    run_analytic(archs, table_entry_bytes=4 if args.float_table else 1)
    if args.run:
        run_timed()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
