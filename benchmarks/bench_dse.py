"""Paper Fig. 11 + Fig. 14 analogue: K-axis and MNK-tile design-space
exploration, under BOTH cost models (mux hardware as in the paper; MXU
realization for our TPU target). See core/dse.py."""

from repro.core import dse


def main():
    print("# Fig11 analogue: K-axis DSE")
    print("k,mux_density_int8lut,mux_density_fp16lut,mxu_score")
    for k in range(1, 9):
        print(f"{k},{dse.mux_density(k):.4f},"
              f"{dse.mux_density(k, lut_bits=16, fp_accum=True):.4f},"
              f"{dse.mxu_cost(k)['score']:.3f}")
    print(f"optimum,mux_int={dse.best_k_mux(8, False)},"
          f"mux_fp={dse.best_k_mux(16, True)},mxu={dse.best_k_mxu()}")
    assert dse.best_k_mux(8, False) == 4      # paper Fig 11 (INT)
    assert dse.best_k_mux(16, True) == 5      # paper Fig 11 (FP)
    assert dse.best_k_mxu() <= 2              # TPU adaptation finding

    print("\n# Fig14 analogue: MNK tile sweep at M*N*K=512 (area-iso)")
    print("m,n,k,bytes_per_mac,table_B,weights_B")
    rows = dse.sweep_tiles(512)
    for r in rows[:6]:
        print(f"{r['m']},{r['n']},{r['k']},{r['bytes_per_mac']:.3f},"
              f"{r['table']:.0f},{r['weights']:.0f}")
    best = rows[0]
    # elongated shape: N >= 4x M at the optimum (paper: M2N64K4)
    assert best["n"] >= 4 * best["m"], best
    print(f"optimum,M{best['m']}N{best['n']}K{best['k']} (elongated)")


if __name__ == "__main__":
    main()
