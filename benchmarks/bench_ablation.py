"""Paper Table 2 analogue: step-by-step ablation of the co-design,
UNPU-style conventional LUT -> LUT Tensor Core (W_INT2 A_INT8 case).

Area model components (normalized units, calibrated so the component
ratios reproduce Table 2's measured trajectory — the *structure* of the
model, table/negation/precompute/adder, is the paper's §3; only the 28nm
gate-cost constants are fitted):

  step                       what changes                       paper   ours
  0 conventional (UNPU+DSE)  full 2^K table, per-cluster        1.000x  1.000x
                             precompute, negation circuit
  1 +reinterpret+symmetrize  2^(K-1) table & precompute (Eq4-5) 1.317x
  2 +negation folding        negation circuit removed (Eq 6)    1.351x
  3 +DFG transform + fusion  precompute leaves the array        1.440x
"""

K = 4
E_FULL = 1 << K
E_HALF = 1 << (K - 1)

# calibrated area components (normalized to conventional total = 1.0)
TABLE_PER_ENTRYBIT = 0.391 / (E_FULL * 8)   # table registers
NEGATION = 0.019                             # runtime bit-flip circuit
PRECOMP_PER_ENTRY = 0.092 / E_FULL           # per-cluster precompute adders
ADDER = 0.499                                # accumulate adder (fixed)


def area(entries, negation, precompute):
    a = entries * 8 * TABLE_PER_ENTRYBIT + ADDER
    if negation:
        a += NEGATION
    if precompute:
        a += entries * PRECOMP_PER_ENTRY
    return a


def main():
    steps = [
        ("conventional_unpu_dse", E_FULL, True, True),
        ("+reinterpret_symmetrize", E_HALF, True, True),
        ("+negation_folding", E_HALF, False, True),
        ("+dfg_fusion (=LUT-TC)", E_HALF, False, False),
    ]
    paper = [1.000, 1.317, 1.351, 1.440]
    print("# Table 2 analogue: co-design ablation (W2A8, K=4)")
    print("step,table_entries,area,density_gain,paper_reported")
    a0 = area(*steps[0][1:])
    for (name, e, neg, pre), p in zip(steps, paper):
        a = area(e, neg, pre)
        print(f"{name},{e},{a:.3f},{a0 / a:.3f}x,{p:.3f}x")
    final = a0 / area(*steps[-1][1:])
    print(f"overall,LUT-TC vs UNPU: {final:.2f}x (paper Table 2: 1.44x)")
    assert abs(final - 1.44) < 0.02


if __name__ == "__main__":
    main()
