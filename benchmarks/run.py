"""Benchmark entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [section ...]

Sections: mpgemm (Fig4/18), dse (Fig11/14), ablation (Table2),
fusion (Table4), table_quant (Table5), e2e (Table1/Fig17),
kernels (§4.3), roofline (§Roofline tables from dry-run JSONs).
"""

import sys
import time


def main() -> None:
    from benchmarks import (bench_ablation, bench_dse, bench_e2e,
                            bench_kernels, bench_mpgemm,
                            bench_precompute_fusion, bench_table_quant,
                            roofline_table)
    sections = {
        "dse": bench_dse.main,
        "ablation": bench_ablation.main,
        "e2e": bench_e2e.main,
        "table_quant": bench_table_quant.main,
        "fusion": bench_precompute_fusion.main,
        "mpgemm": bench_mpgemm.main,
        "kernels": bench_kernels.main,
        "roofline": roofline_table.main,
    }
    want = sys.argv[1:] or list(sections)
    for name in want:
        t0 = time.time()
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        try:
            sections[name]()
        except Exception as e:  # keep the suite running; report at the end
            print(f"SECTION FAILED: {name}: {type(e).__name__}: {e}")
            raise
        print(f"== {name} done in {time.time()-t0:.1f}s")


if __name__ == '__main__':
    main()
