"""Serving-engine benchmark: per-tick host-driven decode vs device-resident
chunked decode.

The paper's payoff regime is batched decode (memory-bound GEMV-shaped
mpGEMM); the engine's job is to not spend that win on host round-trips.
This bench runs the SAME request workload through the engine at a sweep of
``decode_chunk`` settings (1 = the historical one-dispatch-per-token loop)
and reports, per setting:

  * tok/s over the whole run (prefill + decode wall-clock) plus a
    decode-only tok/s that excludes prefill/admission overhead,
  * ``compile_ms`` — the AOT compile cost of the decode program for that
    chunk shape, measured separately so compile churn can never masquerade
    as a steady-state latency cliff (see docs/KERNEL_TUNING.md),
  * host syncs per generated token (measured from engine counters; the
    device-resident loop targets <= 1/decode_chunk),
  * p50/p95 decode-chunk dispatch latency (best of ``--repeats`` measured
    reps on one warmed engine).

Results go to stdout and, with ``--out``, to a JSON file so the perf
trajectory is machine-readable (``make bench-serving`` writes
``BENCH_serving.json``).

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke
    PYTHONPATH=src python benchmarks/bench_serving.py --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.serving.engine import Request, ServingEngine


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 24))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=max_new))
    return reqs


def run_one(cfg, params, *, decode_chunk, args):
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, decode_chunk=decode_chunk,
                        prefill_chunk=args.prefill_chunk)

    # Attribute XLA compile time for this chunk shape explicitly (AOT
    # lower+compile; never lands on the measured clock). Telling compile
    # from steady-state is the whole decode_chunk=16 post-mortem: a chunk
    # sweep that recompiles inside the measured window reports a latency
    # cliff that has nothing to do with the kernel schedule.
    t0 = time.perf_counter()
    eng._decode.lower(eng.params, eng.state).compile()
    compile_ms = (time.perf_counter() - t0) * 1e3

    # warmup: populate the jit dispatch cache for decode/prefill/merge
    for r in _requests(cfg, args.max_batch, 2, seed=1):
        eng.submit(r)
    eng.run_to_completion()

    # steady state: repeat the measured workload on the SAME engine (no
    # recompiles between reps) and keep the best rep — isolates kernel
    # throughput from scheduler/allocator noise on a shared host.
    best = None
    for _ in range(max(1, args.repeats)):
        eng.reset()
        for r in _requests(cfg, args.requests, args.max_new, seed=0):
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_to_completion()
        wall = time.perf_counter() - t0
        st = eng.stats()
        st.update({
            "wall_s": wall,
            "tok_s": st["decode_tokens"] / wall,
            "compile_ms": compile_ms,
            "sync_bound": 1.0 / decode_chunk,
            "meets_sync_bound":
                st["host_syncs_per_token"] <= 1.0 / decode_chunk + 1e-12,
        })
        if best is None or st["tok_s"] > best["tok_s"]:
            best = st
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="published config (default: reduced smoke dims)")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest footprint: fewer requests/tokens")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--decode-chunks", default="1,8,16",
                    help="comma list of decode_chunk settings; 1 = the "
                         "per-tick baseline")
    ap.add_argument("--mode", default="lut_xla")
    ap.add_argument("--weight-bits", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured reps per chunk setting on one warmed "
                         "engine; best rep is reported")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.max_new = 4, 16

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_reduced(args.arch))
    cfg = cfg.replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode=args.mode, weight_bits=args.weight_bits)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)

    chunks = [int(c) for c in args.decode_chunks.split(",")]
    runs = []
    for dc in chunks:
        st = run_one(cfg, params, decode_chunk=dc, args=args)
        runs.append(st)
        print(f"decode_chunk={dc:>3}: {st['tok_s']:8.1f} tok/s "
              f"(decode-only {st['decode_tok_s']:8.1f})  "
              f"syncs/tok {st['host_syncs_per_token']:.4f} "
              f"(bound {st['sync_bound']:.4f}, "
              f"{'OK' if st['meets_sync_bound'] else 'VIOLATED'})  "
              f"chunk p50 {st['p50_chunk_ms']:.1f} ms "
              f"p95 {st['p95_chunk_ms']:.1f} ms  "
              f"compile {st['compile_ms']:.0f} ms")

    result = {
        "bench": "serving",
        "arch": args.arch,
        "reduced": not args.full,
        "mode": args.mode,
        "weight_bits": args.weight_bits,
        "max_batch": args.max_batch,
        "max_seq": args.max_seq,
        "requests": args.requests,
        "max_new": args.max_new,
        "runs": runs,
    }
    base = next((r for r in runs if r["decode_chunk"] == 1), None)
    best = max(runs, key=lambda r: r["tok_s"])
    if base is not None:
        result["speedup_best_vs_per_tick"] = best["tok_s"] / base["tok_s"]
        print(f"best ({best['decode_chunk']}-token chunks) vs per-tick: "
              f"{result['speedup_best_vs_per_tick']:.2f}x")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
