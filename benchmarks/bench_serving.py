"""Serving-engine benchmark: per-tick host-driven decode vs device-resident
chunked decode.

The paper's payoff regime is batched decode (memory-bound GEMV-shaped
mpGEMM); the engine's job is to not spend that win on host round-trips.
This bench runs the SAME request workload through the engine at a sweep of
``decode_chunk`` settings (1 = the historical one-dispatch-per-token loop)
and reports, per setting:

  * tok/s over the whole run (prefill + decode wall-clock) plus a
    decode-only tok/s that excludes prefill/admission overhead,
  * ``compile_ms`` — the AOT compile cost of the decode program for that
    chunk shape, measured separately so compile churn can never masquerade
    as a steady-state latency cliff (see docs/KERNEL_TUNING.md),
  * host syncs per generated token (measured from engine counters; the
    device-resident loop targets <= 1/decode_chunk),
  * p50/p95 decode-chunk dispatch latency (best of ``--repeats`` measured
    reps on one warmed engine).

Three block-paged-pool scenarios ride along (skip with ``--no-paged``):

  * ``paged_compare`` — the SAME workload on the dense engine vs the paged
    pool at equal batch: decode tok/s ratio (the paging overhead), peak
    cache HBM bytes, slot occupancy, admission-blocked rate.
    ``--assert-paged-ratio R`` exits nonzero if paged decode tok/s drops
    below R x dense (CI gate).
  * ``capacity`` — fixed cache-HBM budget: a dense engine spends
    max_batch x max_seq whether prompts need it or not; the paged pool
    holds the same bytes but admits by actual block need, so ragged
    prompts pack more concurrent slots into the budget.
  * ``prefix_fanout`` — one shared system prompt fanned out over N
    requests with distinct suffixes: followers reuse the prefix blocks by
    reference and skip those prefill chunks entirely (prefill wall-time
    ratio reported).

Results go to stdout and, with ``--out``, to a JSON file so the perf
trajectory is machine-readable (``make bench-serving`` writes
``BENCH_serving.json``).

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke
    PYTHONPATH=src python benchmarks/bench_serving.py --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.obs.metrics import MetricsRegistry
from repro.serving.engine import Request, ServingEngine


def assert_finite(obj, path="result"):
    """Every numeric field in the emitted bench JSON must be finite.

    A NaN/inf slipping into a rate (e.g. a blocked-admissions ratio with
    zero attempts) poisons downstream trend tooling silently; fail the
    bench loudly instead."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            assert_finite(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            assert_finite(v, f"{path}[{i}]")
    elif isinstance(obj, bool) or obj is None or isinstance(obj, str):
        pass
    elif isinstance(obj, (int, float, np.integer, np.floating)):
        if not np.isfinite(obj):
            raise AssertionError(f"non-finite bench field {path} = {obj!r}")


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 24))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=max_new))
    return reqs


def run_one(cfg, params, *, decode_chunk, args, **engine_kw):
    # per-run registry: the engine's latency/occupancy series emit through
    # repro.obs.metrics and ride the bench JSON as a snapshot, so the bench
    # exercises the same exposition path serve.py --metrics-out uses
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, decode_chunk=decode_chunk,
                        prefill_chunk=args.prefill_chunk,
                        metrics=MetricsRegistry(), **engine_kw)

    # Attribute XLA compile time for this chunk shape explicitly (AOT
    # lower+compile; never lands on the measured clock). Telling compile
    # from steady-state is the whole decode_chunk=16 post-mortem: a chunk
    # sweep that recompiles inside the measured window reports a latency
    # cliff that has nothing to do with the kernel schedule.
    t0 = time.perf_counter()
    eng._decode.lower(eng.params, eng.state).compile()
    compile_ms = (time.perf_counter() - t0) * 1e3

    # warmup: populate the jit dispatch cache for decode/prefill/merge
    for r in _requests(cfg, args.max_batch, 2, seed=1):
        eng.submit(r)
    eng.run_to_completion()

    # steady state: repeat the measured workload on the SAME engine (no
    # recompiles between reps) and keep the best rep — isolates kernel
    # throughput from scheduler/allocator noise on a shared host.
    best = None
    for _ in range(max(1, args.repeats)):
        eng.reset()
        for r in _requests(cfg, args.requests, args.max_new, seed=0):
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_to_completion()
        wall = time.perf_counter() - t0
        st = eng.stats()
        st.update({
            "wall_s": wall,
            "tok_s": st["decode_tokens"] / wall,
            "compile_ms": compile_ms,
            "sync_bound": 1.0 / decode_chunk,
            "meets_sync_bound":
                st["host_syncs_per_token"] <= 1.0 / decode_chunk + 1e-12,
        })
        if best is None or st["tok_s"] > best["tok_s"]:
            best = st
    # registry snapshot of the FINAL measured rep (reset() zeroes the
    # engine_* series per rep): histogram summaries + blockpool counters
    best["metrics"] = eng.metrics_snapshot()
    return best


def _warmed_engine(cfg, params, *, n_warm=2, **kw):
    """Engine with its decode/prefill programs compiled off the clock."""
    eng = ServingEngine(cfg, params, **kw)
    eng._decode.lower(eng.params, eng.state).compile()
    for r in _requests(cfg, n_warm, 2, seed=1):
        eng.submit(r)
    eng.run_to_completion()
    eng.reset()
    return eng


def bench_paged_compare(cfg, params, args):
    """Equal-batch dense vs paged: the paging overhead on decode tok/s,
    plus the pool observability the dense engine cannot offer."""
    # enough decode work per rep (and enough reps) that the ratio measures
    # steady-state gather overhead, not dispatch jitter on a short burst
    n_req, max_new = max(args.requests, 8), max(args.max_new, 32)
    out = {}
    for label, kw in (("dense", {}),
                      ("paged", {"cache_block_size": args.cache_block_size})):
        eng = _warmed_engine(cfg, params, max_batch=args.max_batch,
                             max_seq=args.max_seq, decode_chunk=8,
                             prefill_chunk=args.prefill_chunk, **kw)
        best = None
        for _ in range(max(3, args.repeats)):
            eng.reset()
            for r in _requests(cfg, n_req, max_new, seed=0):
                eng.submit(r)
            eng.run_to_completion()
            st = eng.stats()
            if best is None or st["decode_tok_s"] > best["decode_tok_s"]:
                best = st
        out[label] = {k: best[k] for k in (
            "decode_tok_s", "cache_hbm_bytes", "slot_occupancy",
            "peak_active_slots", "admit_attempts", "admit_blocked",
            "admission_blocked_rate", "prefill_s", "prefill_tokens")}
    out["decode_tok_s_ratio"] = (out["paged"]["decode_tok_s"]
                                 / out["dense"]["decode_tok_s"])
    print(f"paged_compare: dense {out['dense']['decode_tok_s']:.1f} tok/s "
          f"({out['dense']['cache_hbm_bytes'] / 1e6:.2f} MB cache) vs paged "
          f"{out['paged']['decode_tok_s']:.1f} tok/s "
          f"({out['paged']['cache_hbm_bytes'] / 1e6:.2f} MB) -> ratio "
          f"{out['decode_tok_s_ratio']:.3f}")
    return out


def bench_capacity(cfg, params, args):
    """Fixed cache-HBM budget: dense spends max_batch x max_seq up front;
    the paged pool holds the same bytes but admits by block need, so the
    ragged workload packs more concurrent slots into the budget."""
    bs = args.cache_block_size
    dense_batch = args.max_batch
    # pool sized to EXACTLY the dense engine's attention bytes (same block
    # count), but spread over 4x the slots
    nb = dense_batch * (args.max_seq // bs)
    paged_batch = dense_batch * 4
    n_req = max(args.requests, 2 * paged_batch)
    max_new = max(4, min(args.max_new, 8))  # short gens: admission-bound
    out = {}
    for label, kw in (
            ("dense", {"max_batch": dense_batch}),
            ("paged", {"max_batch": paged_batch, "cache_block_size": bs,
                       "num_cache_blocks": nb})):
        eng = _warmed_engine(cfg, params, max_seq=args.max_seq,
                             decode_chunk=8,
                             prefill_chunk=args.prefill_chunk, **kw)
        for r in _requests(cfg, n_req, max_new, seed=0):
            eng.submit(r)
        eng.run_to_completion()
        st = eng.stats()
        out[label] = {k: st[k] for k in (
            "cache_hbm_bytes", "peak_active_slots", "slot_occupancy",
            "admission_blocked_rate", "decode_tok_s")}
        out[label]["max_batch"] = kw["max_batch"]
    out["peak_slots_ratio"] = (out["paged"]["peak_active_slots"]
                               / max(1, out["dense"]["peak_active_slots"]))
    print(f"capacity (fixed budget): dense peaks at "
          f"{out['dense']['peak_active_slots']} slots "
          f"({out['dense']['cache_hbm_bytes'] / 1e6:.2f} MB); paged packs "
          f"{out['paged']['peak_active_slots']} "
          f"({out['paged']['cache_hbm_bytes'] / 1e6:.2f} MB) -> "
          f"{out['peak_slots_ratio']:.1f}x concurrent slots")
    return out


def bench_prefix_fanout(cfg, params, args):
    """One system prompt x N distinct suffixes: followers reuse the shared
    prefix blocks by reference instead of re-prefilling them."""
    bs = args.cache_block_size
    sys_len = 8 * bs                       # 8 fully-shareable blocks
    max_seq = max(args.max_seq, 2 * sys_len)
    n_fan = 8
    rng = np.random.default_rng(2)
    sys_p = rng.integers(0, cfg.vocab_size, sys_len, dtype=np.int32)
    prompts = [np.concatenate([sys_p, [i % cfg.vocab_size]]).astype(np.int32)
               for i in range(n_fan)]
    out = {"fanout": n_fan, "system_prompt_tokens": sys_len}
    for label, kw in (("no_prefix", {}), ("prefix", {"prefix_cache": True})):
        eng = _warmed_engine(cfg, params, max_batch=args.max_batch,
                             max_seq=max_seq, decode_chunk=8,
                             prefill_chunk=args.prefill_chunk,
                             cache_block_size=bs, **kw)
        best = None
        for _ in range(max(1, args.repeats)):
            eng.reset()
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
            eng.run_to_completion()
            st = eng.stats()
            if best is None or st["prefill_s"] < best["prefill_s"]:
                best = st
        keys = ["prefill_s", "prefill_dispatches", "prefill_tokens",
                "prefill_tokens_reused"]
        if "prefix_cache" in best:
            out["prefix_cache"] = best["prefix_cache"]
        out[label] = {k: best[k] for k in keys}
    out["prefill_time_ratio"] = (out["no_prefix"]["prefill_s"]
                                 / max(1e-9, out["prefix"]["prefill_s"]))
    out["prefill_time_saved_s"] = (out["no_prefix"]["prefill_s"]
                                   - out["prefix"]["prefill_s"])
    print(f"prefix_fanout ({n_fan} x {sys_len}-token system prompt): "
          f"prefill {out['no_prefix']['prefill_s'] * 1e3:.1f} ms -> "
          f"{out['prefix']['prefill_s'] * 1e3:.1f} ms "
          f"({out['prefill_time_ratio']:.1f}x less prefill time; "
          f"{out['prefix']['prefill_tokens_reused']} tokens reused)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="published config (default: reduced smoke dims)")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest footprint: fewer requests/tokens")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--decode-chunks", default="1,8,16",
                    help="comma list of decode_chunk settings; 1 = the "
                         "per-tick baseline")
    ap.add_argument("--mode", default="lut_xla")
    ap.add_argument("--weight-bits", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured reps per chunk setting on one warmed "
                         "engine; best rep is reported")
    ap.add_argument("--cache-block-size", type=int, default=8,
                    help="block size for the paged-pool scenarios")
    ap.add_argument("--no-paged", action="store_true",
                    help="skip the paged-pool scenarios")
    ap.add_argument("--assert-paged-ratio", type=float, default=None,
                    metavar="R",
                    help="exit nonzero unless paged decode tok/s >= R x "
                         "dense (CI gate)")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.max_new = 4, 16

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_reduced(args.arch))
    cfg = cfg.replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode=args.mode, weight_bits=args.weight_bits)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)

    chunks = [int(c) for c in args.decode_chunks.split(",")]
    runs = []
    for dc in chunks:
        st = run_one(cfg, params, decode_chunk=dc, args=args)
        runs.append(st)
        print(f"decode_chunk={dc:>3}: {st['tok_s']:8.1f} tok/s "
              f"(decode-only {st['decode_tok_s']:8.1f})  "
              f"syncs/tok {st['host_syncs_per_token']:.4f} "
              f"(bound {st['sync_bound']:.4f}, "
              f"{'OK' if st['meets_sync_bound'] else 'VIOLATED'})  "
              f"chunk p50 {st['p50_chunk_ms']:.1f} ms "
              f"p95 {st['p95_chunk_ms']:.1f} ms  "
              f"compile {st['compile_ms']:.0f} ms")

    result = {
        "bench": "serving",
        "arch": args.arch,
        "reduced": not args.full,
        "mode": args.mode,
        "weight_bits": args.weight_bits,
        "max_batch": args.max_batch,
        "max_seq": args.max_seq,
        "requests": args.requests,
        "max_new": args.max_new,
        "runs": runs,
    }
    base = next((r for r in runs if r["decode_chunk"] == 1), None)
    best = max(runs, key=lambda r: r["tok_s"])
    if base is not None:
        result["speedup_best_vs_per_tick"] = best["tok_s"] / base["tok_s"]
        print(f"best ({best['decode_chunk']}-token chunks) vs per-tick: "
              f"{result['speedup_best_vs_per_tick']:.2f}x")

    failed = []
    if not args.no_paged:
        result["paged_compare"] = bench_paged_compare(cfg, params, args)
        result["capacity"] = bench_capacity(cfg, params, args)
        result["prefix_fanout"] = bench_prefix_fanout(cfg, params, args)
        if args.assert_paged_ratio is not None:
            r = result["paged_compare"]["decode_tok_s_ratio"]
            if r < args.assert_paged_ratio:
                failed.append(f"paged decode tok/s ratio {r:.3f} < "
                              f"{args.assert_paged_ratio}")
    assert_finite(result)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    if failed:
        print("ASSERTION FAILED: " + "; ".join(failed))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
