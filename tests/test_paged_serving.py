"""Block-paged serving engine: bit-exact parity with the dense engine
across cache families (attention, int8 attention, SSM, hybrid), shared-
prefix reuse (reference sharing + copy-on-write), and admission blocking
under a constrained pool."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.serving.engine import Request, ServingEngine


def _cfg(arch="tinyllama-1.1b", **over):
    return registry.get_reduced(arch).replace(
        activation_dtype=jnp.float32, **over)


@pytest.fixture(scope="module")
def tl():
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    return cfg, params


def _run(cfg, params, prompts, n_new, *, temperature=0.0, seed=0, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefill_chunk", 4)
    eng = ServingEngine(cfg, params, seed=seed, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n,
                    temperature=temperature, top_k=5 if temperature else 0)
            for i, (p, n) in enumerate(zip(prompts, n_new))]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    assert all(r.done for r in reqs)
    return eng, [r.output for r in reqs]


def _ragged(cfg, rng, plens=(5, 8, 11, 3, 6)):
    return [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
            for p in plens]


# ---------------------------------------------------------------------------
# paged == dense, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decode_chunk", [1, 8])
def test_paged_matches_dense_greedy(tl, decode_chunk):
    """5 ragged requests > 2 slots (mid-stream retire/refill): the paged
    engine's greedy output is bit-identical to the dense engine's, at both
    sync-every-token and chunked decode."""
    cfg, params = tl
    prompts = _ragged(cfg, np.random.default_rng(0))
    n_new = [4, 6, 3, 5, 4]
    _, dense = _run(cfg, params, prompts, n_new, decode_chunk=decode_chunk)
    _, paged = _run(cfg, params, prompts, n_new, decode_chunk=decode_chunk,
                    cache_block_size=8)
    assert paged == dense


def test_paged_matches_dense_sampled(tl):
    """Same PRNG seed + same admission order => the sampled streams are
    bit-identical too (sampling consumes logits that must match exactly)."""
    cfg, params = tl
    prompts = _ragged(cfg, np.random.default_rng(1))
    n_new = [4, 6, 3, 5, 4]
    _, dense = _run(cfg, params, prompts, n_new, decode_chunk=8,
                    temperature=1.3, seed=11)
    _, paged = _run(cfg, params, prompts, n_new, decode_chunk=8,
                    temperature=1.3, seed=11, cache_block_size=8)
    assert paged == dense


def test_paged_matches_dense_int8_kv(tl):
    """int8 KV pool (4-leaf: codes + per-(pos, head) scales) pages all four
    leaves through the same table and stays bit-exact vs dense int8."""
    cfg, params = tl
    cfg = cfg.replace(kv_cache_dtype="int8")
    prompts = _ragged(cfg, np.random.default_rng(2), (5, 9, 12))
    _, dense = _run(cfg, params, prompts, [4, 5, 4], decode_chunk=4)
    _, paged = _run(cfg, params, prompts, [4, 5, 4], decode_chunk=4,
                    cache_block_size=8)
    assert paged == dense


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-7b"])
def test_paged_matches_dense_ssm_and_hybrid(arch):
    """Pure-SSM caches have no sequence axis (nothing pooled); hybrid
    stacks mix pooled attention KV with slot-resident mamba state. Both
    must stay bit-exact under paging."""
    cfg = _cfg(arch)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    prompts = _ragged(cfg, np.random.default_rng(3), (6, 9, 5))
    _, dense = _run(cfg, params, prompts, [4, 4, 4], decode_chunk=4)
    _, paged = _run(cfg, params, prompts, [4, 4, 4], decode_chunk=4,
                    cache_block_size=8)
    assert paged == dense


# ---------------------------------------------------------------------------
# shared-prefix reuse
# ---------------------------------------------------------------------------

def test_prefix_fanout_skips_prefill_and_stays_exact(tl):
    """One 32-token system prompt (4 full blocks) fanned out over 6
    requests with distinct 1-token suffixes: followers reuse the shared
    blocks by reference, cutting prefill dispatches, with identical
    output."""
    cfg, params = tl
    rng = np.random.default_rng(4)
    sys_p = rng.integers(0, cfg.vocab_size, 32, dtype=np.int32)
    prompts = [np.concatenate([sys_p, [i]]).astype(np.int32)
               for i in range(6)]
    e0, base = _run(cfg, params, prompts, [4] * 6, cache_block_size=8)
    e1, shared = _run(cfg, params, prompts, [4] * 6, cache_block_size=8,
                      prefix_cache=True)
    assert shared == base
    s0, s1 = e0.stats(), e1.stats()
    assert s1["prefill_dispatches"] < s0["prefill_dispatches"] / 2
    assert s1["prefill_tokens_reused"] > 0
    assert s1["prefix_cache"]["hits"] > 0


def test_prefix_cow_identical_prompts(tl):
    """Identical prompts whose length is an exact block multiple: the
    divergence block is copy-on-write (decode rewrites its last position in
    a private copy), so outputs still match the dense engine exactly."""
    cfg, params = tl
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, 32, dtype=np.int32)  # 4 * bs
    prompts = [p.copy() for _ in range(4)]
    _, dense = _run(cfg, params, prompts, [4] * 4)
    e, cow = _run(cfg, params, prompts, [4] * 4, cache_block_size=8,
                  prefix_cache=True)
    assert cow == dense
    assert e.stats()["prefix_cache"]["hits"] > 0


def test_prefix_disabled_for_slot_resident_state():
    """Hybrid stacks hold slot-resident mamba state that cannot fan out by
    block reference: asking for prefix caching warns and disables it."""
    cfg = _cfg("zamba2-7b")
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    with pytest.warns(UserWarning, match="prefix caching"):
        eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                            cache_block_size=8, prefix_cache=True)
    assert not eng.prefix_caching


# ---------------------------------------------------------------------------
# admission blocking / pool accounting
# ---------------------------------------------------------------------------

def test_blocked_admission_defers_then_completes(tl):
    """A pool that can only hold one reservation at a time serializes
    admissions through blocked attempts — every request still completes
    with its full budget, bit-exact vs dense."""
    cfg, params = tl
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, 20, dtype=np.int32)
               for _ in range(3)]
    # need = ceil((20+40)/8) = 8 blocks = the whole usable pool
    eng, out = _run(cfg, params, prompts, [40] * 3, decode_chunk=4,
                    cache_block_size=8, num_cache_blocks=9)
    assert all(len(o) == 40 for o in out)
    st = eng.stats()
    assert st["admit_blocked"] > 0
    assert st["admission_blocked_rate"] > 0
    assert st["blocks_in_use"] == 0  # everything retired back to the pool
    _, dense = _run(cfg, params, prompts, [40] * 3, decode_chunk=4)
    assert out == dense


def test_infeasible_reservation_raises(tl):
    cfg, params = tl
    with pytest.raises(ValueError, match="cache_block_size"):
        ServingEngine(cfg, params, max_batch=2, max_seq=64,
                      cache_block_size=7)  # does not divide max_seq
    with pytest.raises(ValueError, match="num_cache_blocks"):
        ServingEngine(cfg, params, max_batch=2, max_seq=64,
                      cache_block_size=8, num_cache_blocks=4)
