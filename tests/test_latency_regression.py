"""Decode-chunk latency-regression suite: the decode_chunk=16 cliff.

A historical BENCH_serving.json showed chunk=16 p50 latency at ~4.6x
chunk=8 (15 -> 69 ms) with tok/s cut in half — a cliff the chunk sweep
should never have: doubling the chunk doubles the work per dispatch, so
p50 should scale ~linearly and decode-only throughput should be flat.
The post-mortem (docs/KERNEL_TUNING.md) attributed it to compile time and
state-copy overhead leaking into a small measured sample, not to the
kernel schedule. This suite locks the invariant in:

  * chunk=16 p50 chunk latency <= 2.5x chunk=8 (linear scaling would be
    2.0x; the slack absorbs CPU-CI noise);
  * chunk=16 decode-only tok/s within 25% of chunk=8.

Runs on the reduced tinyllama config on CPU with relaxed bounds, best-of-2
reps per setting on one warmed engine (compile never on the clock).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.serving.engine import Request, ServingEngine

REQUESTS = 8
MAX_NEW = 24
MAX_BATCH = 4
REPS = 3


@pytest.fixture(scope="module")
def served_model():
    cfg = registry.get_reduced("tinyllama-1.1b")
    cfg = cfg.replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(mpgemm_mode="lut_xla", weight_bits=2)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    return cfg, params


def _requests(cfg, n, max_new, seed):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 24)),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run_chunk_setting(cfg, params, decode_chunk):
    """Best-per-metric stats over REPS measured runs on one warmed engine.

    Best-of aggregation (min p50, max tok/s, independently) is deliberate:
    a structural cliff degrades every rep, while CPU-CI scheduler noise
    rarely hits all REPS runs of both chunk settings — so the bounds stay
    tight without flaking under a loaded host.
    """
    eng = ServingEngine(cfg, params, max_batch=MAX_BATCH, max_seq=64,
                        decode_chunk=decode_chunk, prefill_chunk=16)
    # warmup: compile decode/prefill/merge off the clock
    for r in _requests(cfg, MAX_BATCH, 2, seed=1):
        eng.submit(r)
    eng.run_to_completion()

    reps = []
    for _ in range(REPS):
        eng.reset()
        for r in _requests(cfg, REQUESTS, MAX_NEW, seed=0):
            eng.submit(r)
        eng.run_to_completion()
        reps.append(eng.stats())
    best = dict(reps[-1])
    best["p50_chunk_ms"] = min(r["p50_chunk_ms"] for r in reps)
    best["decode_tok_s"] = max(r["decode_tok_s"] for r in reps)
    return best


def test_no_decode_chunk16_cliff(served_model):
    cfg, params = served_model
    st8 = _run_chunk_setting(cfg, params, 8)
    st16 = _run_chunk_setting(cfg, params, 16)

    # p50 chunk latency scales ~linearly in chunk size (2x work -> ~2x
    # latency); the historical cliff was 4.6x. 2.5x bound = linear + noise.
    assert st16["p50_chunk_ms"] <= 2.5 * max(st8["p50_chunk_ms"], 1.0), (
        f"decode_chunk=16 p50 {st16['p50_chunk_ms']:.1f} ms vs "
        f"chunk=8 {st8['p50_chunk_ms']:.1f} ms — the chunk-16 cliff is back")

    # decode-only throughput must be flat across chunk sizes
    assert st16["decode_tok_s"] >= 0.75 * st8["decode_tok_s"], (
        f"decode_chunk=16 decode tok/s {st16['decode_tok_s']:.0f} vs "
        f"chunk=8 {st8['decode_tok_s']:.0f} — >25% regression")


def test_chunked_decode_sync_bound(served_model):
    """Chunked decode must hold its host-sync contract — syncs per token
    <= 1/decode_chunk — or the latency win is being bought back."""
    cfg, params = served_model
    for dc in (8, 16):
        st = _run_chunk_setting(cfg, params, dc)
        assert st["host_syncs_per_token"] <= 1.0 / dc + 1e-12
