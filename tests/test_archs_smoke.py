"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: instantiate the reduced config, run one forward
(train-style), one prefill+decode round, and one QAT train-gradient step;
assert output shapes and absence of NaNs. The FULL configs are exercised
only via the dry-run (ShapeDtypeStructs, launch/dryrun.py).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.models.transformer import lm_loss

ARCHS = registry.list_archs()


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.family == "audio":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    params = api.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, _, aux = jax.jit(
        lambda p, b: api.forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    for v in aux.values():
        assert not np.isnan(float(v))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_consistent(arch):
    """Prefill+decode must agree with full-sequence forward on the next-token
    logits (cache correctness)."""
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    params = api.init_params(jax.random.key(1), cfg)
    b, s = 2, 12
    batch = _batch(cfg, b, s, seed=1)

    # full forward over s+1 tokens
    rng = np.random.default_rng(2)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 1)), jnp.int32)
    full_batch = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    full_logits, _, _ = api.forward(params, full_batch, cfg)

    # prefill s tokens, then decode the next one
    caches = api.init_cache(cfg, b, s + 1, dtype=jnp.float32)
    _, caches, _ = api.forward(params, batch, cfg, caches=caches, cache_pos=0)
    dec_batch = {"tokens": nxt}
    logits1, _, _ = api.forward(params, dec_batch, cfg, caches=caches,
                                cache_pos=s)
    np.testing.assert_allclose(
        np.asarray(logits1[:, 0], np.float32),
        np.asarray(full_logits[:, s], np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    """One QAT train-gradient step: finite loss, finite grads."""
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    if cfg.quant:
        cfg = cfg.with_quant(qat=True)
    params = api.init_params(jax.random.key(2), cfg)
    batch = _batch(cfg, 2, 8, seed=3)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, _, aux = api.forward(p, batch, cfg)
        loss = lm_loss(logits, labels)
        if "lb_loss" in aux:
            loss = loss + 0.01 * aux["lb_loss"] + 0.001 * aux["router_z_loss"]
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in leaves)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "olmoe-1b-7b",
                                  "falcon-mamba-7b", "zamba2-7b"])
def test_serve_quantized_params(arch):
    """Quantized serving params run and stay close to the fp forward."""
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    cfg = cfg.with_quant(weight_bits=4)  # W4 keeps the reduced nets sane
    params = api.init_params(jax.random.key(3), cfg)
    qparams = api.init_params(jax.random.key(3), cfg, serve_quantized=True)
    batch = _batch(cfg, 2, 8, seed=5)
    ref_logits, _, _ = api.forward(params, batch, cfg.replace(quant=None))
    q_logits, _, _ = api.forward(qparams, batch, cfg)
    ref = np.asarray(ref_logits, np.float32)
    got = np.asarray(q_logits, np.float32)
    assert np.all(np.isfinite(got))
    # W4 quantization: correlation with the fp forward should be high
    cc = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert cc > 0.95, cc


def test_assigned_arch_count():
    assert len(registry.ASSIGNED) == 10
    assert len(ARCHS) == 11  # + paper-bitnet-3b
