"""LMMA descriptor / tile scheduler / DSE cost-model tests (§3.2.2, §3.3)."""

from repro.core import dse
from repro.core.lmma import LMMADescriptor, schedule_tiles


def test_lmma_name_format():
    d = LMMADescriptor(m=2, n=64, k=4096, a_dtype="bf16", w_bits=2)
    assert d.name().startswith("lmma.m2n64k4096.")


def test_schedule_is_elongated_and_fits_vmem():
    d = LMMADescriptor(m=4096, n=8192, k=8192, w_bits=2, k_group=4)
    ts = schedule_tiles(d)
    # elongated: table reuse pushes bn >> bm (paper §3.2.2)
    assert ts.bn >= 2 * ts.bm, (ts.bm, ts.bn)
    assert ts.vmem_bytes <= 64 * 1024 * 1024
    # lane alignment
    assert ts.bn % 128 == 0


def test_schedule_small_problem_clamps():
    d = LMMADescriptor(m=8, n=128, k=64, w_bits=1, k_group=2)
    ts = schedule_tiles(d)
    assert ts.bm >= 8 and ts.bn >= 128 and ts.bg >= 8


def test_dse_paper_and_tpu_optima():
    assert dse.best_k_mux(8, False) == 4   # paper Fig 11 INT
    assert dse.best_k_mux(16, True) == 5   # paper Fig 11 FP
    assert dse.best_k_mxu() <= 2           # TPU adaptation (DESIGN.md §2)


def test_dse_symmetrization_improves_density():
    # Eq. 4-5: halving the table should improve mux compute density
    for k in (2, 3, 4, 5):
        assert dse.mux_density(k, symmetrized=True) > \
            dse.mux_density(k, symmetrized=False)


def test_dse_fusion_improves_density():
    # §3.1.1: removing per-unit precompute improves density
    for k in (2, 4):
        assert dse.mux_density(k, fused_precompute=True) > \
            dse.mux_density(k, fused_precompute=False)


def test_tile_traffic_eq7_eq8():
    r = dse.tile_traffic(2, 64, 4, k_group=4, w_bits=2, lut_bits=8)
    assert r["table"] == 2 * 1 * 8 * 1        # M·G·E·LUT_BIT/8 (Eq. 7)
    assert r["weights"] == 64 * 1 * 4 * 2 / 8  # N·G·K·W_BIT/8 (Eq. 8)
