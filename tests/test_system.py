"""End-to-end system tests: drivers and examples run as a user would run
them (train → loss decreases + checkpoint restart; serve → tokens out;
quantized-convert → compression + agreement)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(args, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, env=env, timeout=timeout, cwd=ROOT)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-3000:]}\nERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert "OK" in out
    assert "2 bits/weight" in out


def test_train_driver_reduces_loss_and_restarts(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = _run(["-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
                "--reduced", "--steps", "40", "--batch", "4", "--seq", "32",
                "--lr", "3e-3", "--ckpt-dir", ckpt, "--ckpt-every", "20"])
    assert "loss" in out
    # restart: resumes from step 40 checkpoint and runs 10 more
    out2 = _run(["-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
                 "--reduced", "--steps", "50", "--batch", "4", "--seq", "32",
                 "--lr", "3e-3", "--ckpt-dir", ckpt, "--ckpt-every", "20"])
    assert "resumed from step 40" in out2


def test_serve_driver():
    out = _run(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b",
                "--reduced", "--requests", "6", "--max-new", "8",
                "--max-batch", "3", "--mode", "lut_xla"])
    assert "served 6 requests" in out


def test_lowbit_convert_example():
    out = _run(["examples/lowbit_convert.py"])
    assert "OK" in out
    # ternary/W2 compress ~14-16x vs fp32 params (embeddings stay fp)
    assert "x," in out


def test_bench_suite_fast_sections():
    out = _run(["-m", "benchmarks.run", "dse", "ablation", "e2e"])
    assert "optimum,mux_int=4" in out
    assert "paper Table 2: 1.44x" in out
