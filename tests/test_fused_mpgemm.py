"""Fused precompute→lookup kernel: parity contracts (interpret mode).

The fused kernel must be indistinguishable from the staged
``table_precompute_pallas`` + ``lut_mpgemm_pallas`` composition:

  * bit-exact on the per_row int8 path (same closed-form scale, exact int32
    accumulation, no cross-block float reduction);
  * float-tolerance-equal for float tables and per_group quantization;
  * equal to the pure-jnp oracle (ref.ref_lut_mpgemm_matmul) everywhere.

Sweeps k_group ∈ {2, 4}, planes ∈ {1, 2, 4} (weight bits), and all three
table-quant modes, plus the dispatch knob (auto/fused/staged) and the
end-to-end mpgemm routing.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.lmma import LMMADescriptor, fused_tile_bytes, select_fusion
from repro.core.mpgemm import mpgemm
from repro.kernels import ops, ref

BLK = dict(block_m=8, block_n=128, block_g=8, interpret=True)


def _mk(m, k, n, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    return a, w


def _staged(a, qw, tq):
    return ops.lut_mpgemm(a, qw, table_quant=tq, fusion="staged", **BLK)


# ---------------------------------------------------------------------------
# parity: fused vs staged composition vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tq", [None, "per_row", "per_group"])
@pytest.mark.parametrize("bits", [1, 2, 4])  # planes ∈ {1, 2, 4}
@pytest.mark.parametrize("k_group", [2, 4])
def test_fused_matches_staged_and_ref(k_group, bits, tq):
    a, w = _mk(8, 64, 128, seed=bits * 10 + k_group)
    qw = Q.quantize(w, bits, k_group=k_group, scheme="symmetric")
    fused = ops.fused_lut_mpgemm(a, qw, table_quant=tq, **BLK)
    staged = _staged(a, qw, tq)
    want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant=tq)
    if tq == "per_row":  # int8 path: bit-exact with the staged pipeline
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))
    else:
        np.testing.assert_allclose(np.asarray(fused), np.asarray(staged),
                                   rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_ternary():
    """BitNet ternary: two ±1 planes sharing one table."""
    a, w = _mk(8, 64, 128, seed=5)
    qw = Q.quantize(w, 2, k_group=4, scheme="ternary")
    fused = ops.fused_lut_mpgemm(a, qw, table_quant="per_row", **BLK)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(_staged(a, qw, "per_row")))


def test_fused_zero_point_correction():
    """Asymmetric weights exercise the rank-1 z' update outside the kernel."""
    a, w = _mk(8, 64, 128, seed=6)
    qw = Q.quantize(w, 2, k_group=4, scheme="asymmetric")
    fused = ops.fused_lut_mpgemm(a, qw, table_quant="per_row", **BLK)
    want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant="per_row")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_unaligned_shapes():
    """M, K, N not multiples of the blocks: zero-padding must be inert."""
    a, w = _mk(13, 72, 130, seed=7)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    fused = ops.fused_lut_mpgemm(a, qw, table_quant="per_row", **BLK)
    want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant="per_row")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_odd_group_count_realigns_blocks():
    """g=3, planes=1: clamping bg to g breaks packed-stream byte alignment
    unless the wrapper realigns (regression: 'K-block must be byte aligned')."""
    a, w = _mk(8, 12, 16, seed=14)
    qw = Q.quantize(w, 1, k_group=4, scheme="symmetric")
    for fusion in ("fused", "staged", "auto"):
        got = ops.lut_mpgemm(a, qw, table_quant="per_row", fusion=fusion,
                             interpret=True)
        want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant="per_row")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_fused_bf16_activations():
    a, w = _mk(8, 64, 128, seed=8, dtype=jnp.bfloat16)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    fused = ops.fused_lut_mpgemm(a, qw, table_quant="per_row", **BLK)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(_staged(a, qw, "per_row")))


# ---------------------------------------------------------------------------
# dispatch: the fusion knob and the LMMA scheduler decision
# ---------------------------------------------------------------------------

def test_fusion_knob_dispatch():
    a, w = _mk(8, 64, 128, seed=9)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    fused = ops.lut_mpgemm(a, qw, table_quant="per_row", fusion="fused", **BLK)
    auto = ops.lut_mpgemm(a, qw, table_quant="per_row", fusion="auto", **BLK)
    staged = _staged(a, qw, "per_row")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(staged))
    with pytest.raises(ValueError):
        ops.lut_mpgemm(a, qw, fusion="bogus", **BLK)


def test_supplied_table_implies_staged():
    """A shared (§3.1.1 amortized) table must short-circuit fusion."""
    a, w = _mk(8, 64, 128, seed=10)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    t = ops.table_precompute(a, 4, "per_row", block_m=8, block_g=8,
                             interpret=True)
    got = ops.lut_mpgemm(a, qw, table=t, fusion="fused", **BLK)
    want = ref.ref_lut_mpgemm_matmul(a, qw, table=t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_select_fusion_vmem_budget():
    from repro.core.lmma import TileSchedule, schedule_tiles
    desc = LMMADescriptor(m=256, n=4096, k=4096, w_bits=2, k_group=4)
    assert select_fusion(desc) == "fused"  # scheduler tiles always fit
    # exactly at the working set the decision flips: one byte under → staged
    ts = schedule_tiles(desc)
    need = fused_tile_bytes(ts.bm, ts.bn, ts.bg, desc)
    assert select_fusion(desc, ts, vmem_budget=need) == "fused"
    assert select_fusion(desc, ts, vmem_budget=need - 1) == "staged"
    # a hand-pinned oversized tile must fall back to staged
    huge = TileSchedule(bm=4096, bn=4096, bg=4096, table_bytes=0,
                        weight_bytes=0, acc_bytes=0, vmem_bytes=0)
    assert select_fusion(desc, huge) == "staged"


def test_fused_tile_bytes_counts_table_block():
    desc = LMMADescriptor(m=64, n=512, k=1024, w_bits=2, k_group=4)
    e = 1 << (desc.k_group - 1)
    got = fused_tile_bytes(8, 128, 16, desc)
    assert got >= 8 * 16 * e * 4  # at least the f32 entries block


# ---------------------------------------------------------------------------
# end-to-end routing: mpgemm(..., fusion=...) with leading batch dims
# ---------------------------------------------------------------------------

def test_make_table_defers_to_fusion():
    """The model path must not force staged by pre-building a shared table
    when the Pallas path will (or may) run fused."""
    from repro.models.layers import make_table
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    base = {"mpgemm_mode": "lut_pallas", "table_quant": "per_row"}
    assert make_table(x, {**base, "fusion": "fused"}) is None
    assert make_table(x, base) is None            # auto → scheduler → fused
    assert make_table(x, {**base, "fusion": "staged"}) is not None
    assert make_table(x, {"mpgemm_mode": "lut_xla"}) is not None
    assert make_table(x, {"mpgemm_mode": "dequant"}) is None


def test_mpgemm_fusion_routing():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    got = mpgemm(x, qw, mode="lut_pallas", fusion="fused", interpret=True)
    want = mpgemm(x, qw, mode="lut_pallas", fusion="staged", interpret=True)
    assert got.shape == (2, 4, 128)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)
