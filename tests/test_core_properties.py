"""Property-based tests (hypothesis) for the paper's core invariants:

  * Eq. 2-3: reinterpretation preserves the represented value exactly;
  * exact bit-serial sign-plane decomposition of the odd grid;
  * Eq. 4-5: table oddness LUT[w] = -LUT[~w]; half-table + folded codes
    reproduce every full-table entry;
  * pack/unpack and fold/unfold are bijections;
  * INT8 table quantization error is bounded by scale/2 per entry;
  * ternary = two equal-weight sign planes.
"""

import numpy as np
import pytest
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; "
    "pip install -r requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core import packing, quantize as Q, reinterpret as R, table as T
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

bits_st = st.sampled_from([1, 2, 3, 4])
kg_st = st.sampled_from([1, 2, 4, 8])


@given(bits=bits_st, data=st.data())
def test_reinterpret_preserves_value(bits, data):
    """s(q-z) == s'(q'-z') for arbitrary s, z, q (Eq. 2-3)."""
    q = data.draw(st.integers(0, (1 << bits) - 1))
    s = data.draw(st.floats(1e-3, 10, allow_nan=False))
    z = data.draw(st.floats(-5, 5, allow_nan=False))
    sp, zp = R.reinterpret_scale_zero(s, z, bits)
    qp = int(np.asarray(R.reinterpret_codes(np.array([q]), bits))[0])
    assert qp == 2 * q - ((1 << bits) - 1)
    # rtol fails spuriously when q ≈ z makes the value ~0; scale the atol by s
    np.testing.assert_allclose(s * (q - z), sp * (qp - zp),
                               rtol=1e-6, atol=s * 1e-6)


@given(bits=bits_st, n=st.integers(1, 5), k=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31))
def test_sign_plane_decomposition_exact(bits, n, k, seed):
    """q' == Σ_b 2^b (2 plane_b - 1), exactly."""
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 1 << bits, size=(n, k)).astype(np.uint8)
    planes = np.asarray(R.codes_to_sign_planes(q, bits)).astype(np.int64)
    qp = sum((1 << b) * (2 * planes[..., b] - 1) for b in range(bits))
    np.testing.assert_array_equal(qp, 2 * q.astype(np.int64) - ((1 << bits) - 1))


@given(kg=st.sampled_from([2, 3, 4, 5]), seed=st.integers(0, 2**31))
def test_table_oddness(kg, seed):
    """Full table satisfies T[w] == -T[~w] (Eq. 4)."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=kg).astype(np.float32)
    full = np.zeros(1 << kg)
    for w in range(1 << kg):
        sigma = np.array([2 * ((w >> i) & 1) - 1 for i in range(kg)])
        full[w] = np.dot(a, sigma)
    inv = (~np.arange(1 << kg)) & ((1 << kg) - 1)
    np.testing.assert_allclose(full, -full[inv], atol=1e-5)


@given(kg=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31))
def test_half_table_with_folded_codes_covers_full_table(kg, seed):
    """Eq. 5-6: half table + (sign, folded idx) reproduces every entry."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(1, kg)).astype(np.float32)
    half = np.asarray(T.table_entries(jnp.asarray(a)[None], kg))[0, 0]  # [E]
    for w in range(1 << kg):
        bits_ = np.array([(w >> i) & 1 for i in range(kg)], np.uint8)
        planes = jnp.asarray(bits_[None, :, None])  # [1, K, 1]
        sign, idx = R.fold_msb_negation(planes, kg)
        s = int(np.asarray(sign)[0, 0, 0])
        e = int(np.asarray(idx)[0, 0, 0])
        sigma = 2 * bits_.astype(np.float32) - 1
        want = float(np.dot(a[0], sigma))
        got = float(half[e]) * (-1.0 if s else 1.0)
        np.testing.assert_allclose(got, want, atol=1e-5)


@given(bits=bits_st, kg=kg_st, n=st.integers(1, 4), g=st.integers(1, 6),
       seed=st.integers(0, 2**31))
def test_pack_unpack_roundtrip(bits, kg, n, g, seed):
    rng = np.random.default_rng(seed)
    sign = jnp.asarray(rng.integers(0, 2, size=(n, g, bits)), jnp.uint8)
    idx = jnp.asarray(rng.integers(0, 1 << (kg - 1), size=(n, g, bits)),
                      jnp.uint8)
    packed = packing.pack_group_codes(sign, idx, kg)
    assert packed.shape[1] == (g * bits * kg + 7) // 8  # true low-bit storage
    s2, i2 = packing.unpack_group_codes(packed, kg, g, bits)
    np.testing.assert_array_equal(np.asarray(sign), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i2))


@given(bits=bits_st, kg=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31))
def test_fold_unfold_roundtrip(bits, kg, seed):
    rng = np.random.default_rng(seed)
    planes = jnp.asarray(rng.integers(0, 2, size=(3, 2 * kg, bits)), jnp.uint8)
    sign, idx = R.fold_msb_negation(planes, kg)
    back = R.unfold_group_codes(sign, idx, kg)
    np.testing.assert_array_equal(np.asarray(planes), np.asarray(back))


@given(kg=st.sampled_from([2, 4]), mode=st.sampled_from(["per_row", "per_group"]),
       seed=st.integers(0, 2**31))
def test_table_quant_error_bound(kg, mode, seed):
    """|dequant(quant(T)) - T| <= scale/2 per entry (+1 ulp of rounding)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(4, 4 * kg)), jnp.float32)
    t_fp = ref.ref_table_precompute(a, kg, None)
    t_q = ref.ref_table_precompute(a, kg, mode)
    err = np.abs(np.asarray(T.dequantize_table(t_q)) - np.asarray(t_fp.values))
    bound = np.asarray(t_q.scale) * 0.5 * 1.001 + 1e-6
    assert np.all(err <= bound)


@given(seed=st.integers(0, 2**31))
def test_ternary_two_plane_decomposition(seed):
    rng = np.random.default_rng(seed)
    t = rng.integers(-1, 2, size=(3, 8)).astype(np.int32)
    planes = np.asarray(R.ternary_to_sign_planes(t)).astype(np.int64)
    recon = ((2 * planes[..., 0] - 1) + (2 * planes[..., 1] - 1)) / 2
    np.testing.assert_array_equal(recon, t)


@given(bits=st.sampled_from([1, 2, 4]), kg=st.sampled_from([2, 4]),
       scheme=st.sampled_from(["symmetric", "asymmetric"]),
       seed=st.integers(0, 2**31))
def test_mpgemm_formulations_agree(bits, kg, scheme, seed):
    """dequant == gather-LUT == matmul-LUT on random problems."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(3, 4 * kg)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 4 * kg)), jnp.float32)
    qw = Q.quantize(w, bits, k_group=kg, scheme=scheme)
    o1 = np.asarray(ref.ref_dequant_mpgemm(a, qw))
    o2 = np.asarray(ref.ref_lut_mpgemm_gather(a, qw))
    o3 = np.asarray(ref.ref_lut_mpgemm_matmul(a, qw))
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(o1, o3, rtol=1e-4, atol=1e-4)


@given(bits=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**31))
def test_quantize_grid(bits, seed):
    """Symmetric-quantized weights land exactly on the odd grid s'·{±1,±3..}."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    qw = Q.quantize_symmetric(w, bits, k_group=4)
    wd = np.asarray(Q.dequantize(qw))
    ratio = wd / np.asarray(qw.scale)[:, None]
    # ratios must be odd integers within the grid
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-4)
    assert np.all(np.abs(ratio) <= (1 << bits) - 1 + 1e-4)
    odd = np.abs(np.round(ratio)) % 2
    assert np.all(odd == 1)
