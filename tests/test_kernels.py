"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes, dtypes, bit-widths, k_group, and table-quant modes, asserting
allclose against ref.py. These are the kernel contracts for real TPU runs.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core import table as T
from repro.kernels import ops, ref


def _mk(m, k, n, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    return a, w


# ---------------------------------------------------------------------------
# table_precompute kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_group", [2, 4])
@pytest.mark.parametrize("tq", [None, "per_row", "per_group"])
@pytest.mark.parametrize("m,k", [(8, 64), (33, 128)])
def test_table_precompute_matches_oracle(k_group, tq, m, k):
    a, _ = _mk(m, k, 1)
    got = ops.table_precompute(a, k_group, tq, block_m=8, block_g=8,
                               interpret=True)
    want = ref.ref_table_precompute(a, k_group, tq)
    np.testing.assert_allclose(np.asarray(T.dequantize_table(got)),
                               np.asarray(T.dequantize_table(want)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.rowsum), np.asarray(want.rowsum),
                               rtol=1e-5, atol=1e-5)
    if tq is not None:
        # int8 codes must match the oracle exactly (shared closed-form scale)
        np.testing.assert_array_equal(np.asarray(got.values),
                                      np.asarray(want.values))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_table_precompute_dtypes(dtype):
    a, _ = _mk(16, 64, 1, dtype=dtype)
    got = ops.table_precompute(a, 4, "per_row", block_m=8, block_g=4,
                               interpret=True)
    want = ref.ref_table_precompute(a, 4, "per_row")
    np.testing.assert_allclose(np.asarray(T.dequantize_table(got)),
                               np.asarray(T.dequantize_table(want)),
                               rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# lut_mpgemm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,bits", [("symmetric", 1), ("symmetric", 2),
                                         ("symmetric", 4), ("asymmetric", 2),
                                         ("ternary", 2)])
@pytest.mark.parametrize("k_group", [2, 4])
def test_lut_kernel_schemes(scheme, bits, k_group):
    a, w = _mk(16, 128, 384)
    qw = Q.quantize(w, bits, k_group=k_group, scheme=scheme)
    want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant=None)
    got = ops.lut_mpgemm(a, qw, table_quant=None, fusion="staged",
                         block_m=8, block_n=128, block_g=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tq", ["per_row", "per_group"])
def test_lut_kernel_table_quant(tq):
    a, w = _mk(16, 128, 256, seed=3)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant=tq)
    got = ops.lut_mpgemm(a, qw, table_quant=tq, fusion="staged",
                         block_m=8, block_n=128, block_g=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(8, 64, 128), (40, 256, 128), (8, 512, 640)])
def test_lut_kernel_shape_sweep(m, k, n):
    a, w = _mk(m, k, n, seed=m + k + n)
    qw = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    want = ref.ref_lut_mpgemm_matmul(a, qw, table_quant="per_row")
    got = ops.lut_mpgemm(a, qw, table_quant="per_row", fusion="staged",
                         block_m=8, block_n=128, block_g=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_lut_kernel_fused_precomputed_table():
    """DFG split: caller precomputes the table once, shares it."""
    a, w = _mk(16, 128, 256, seed=9)
    qw1 = Q.quantize(w, 2, k_group=4, scheme="symmetric")
    qw2 = Q.quantize(w * 0.5 + 0.1, 2, k_group=4, scheme="symmetric")
    t = ops.table_precompute(a, 4, "per_row", block_m=8, block_g=8,
                             interpret=True)
    for qw in (qw1, qw2):
        want = ref.ref_lut_mpgemm_matmul(a, qw, table=t)
        got = ops.lut_mpgemm(a, qw, table=t, block_m=8, block_n=128,
                             block_g=8, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dequant_mpgemm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,bits", [("symmetric", 1), ("symmetric", 2),
                                         ("symmetric", 4), ("asymmetric", 4),
                                         ("ternary", 2)])
def test_dequant_kernel(scheme, bits):
    a, w = _mk(24, 128, 256, seed=7)
    qw = Q.quantize(w, bits, k_group=4, scheme=scheme)
    want = ref.ref_dequant_mpgemm(a, qw)
    got = ops.dequant_mpgemm(a, qw, block_m=8, block_n=128, block_g=8,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k_group", [1, 2, 4, 8])
def test_dequant_kernel_k_groups(k_group):
    a, w = _mk(8, 64, 128, seed=11)
    qw = Q.quantize(w, 2, k_group=k_group, scheme="symmetric")
    want = ref.ref_dequant_mpgemm(a, qw)
    got = ops.dequant_mpgemm(a, qw, block_m=8, block_n=128, block_g=8,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
