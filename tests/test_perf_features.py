"""Tests for the §Perf optimization features: int8 KV cache, offline-CW
weight format, flash-decode shard_map, shard_map MoE dispatch."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import quantize as Q
from repro.kernels import ref
from repro.models import api

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code, devices=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # forced host devices exist only on the CPU backend; pinning it also
    # skips the accelerator-plugin probe (a sleep-poll that starves 1-cpu
    # boxes)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"OUT:\n{r.stdout}\nERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_int8_kv_cache_close_to_fp():
    cfg = registry.get_reduced("tinyllama-1.1b").replace(
        activation_dtype=jnp.float32).with_quant(weight_bits=4)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    b, s = 2, 12
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (b, s + 1)), jnp.int32)

    def run(dtype):
        caches = api.init_cache(cfg, b, s + 1, dtype=dtype)
        _, caches, _ = api.forward(params, {"tokens": toks[:, :s]}, cfg,
                                   caches=caches, cache_pos=0)
        lg, _, _ = api.forward(params, {"tokens": toks[:, s:]}, cfg,
                               caches=caches, cache_pos=s)
        return np.asarray(lg[:, 0], np.float32)

    ref_l, i8_l = run(jnp.float32), run("int8")
    cc = np.corrcoef(ref_l.ravel(), i8_l.ravel())[0, 1]
    assert cc > 0.999, cc


def test_cw_format_exact_vs_packed():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    qw = Q.quantize(w, 2, k_group=2)
    qcw = Q.to_cw_format(qw)
    assert qcw.packed is None and qcw.cw.dtype == jnp.int8
    o1 = ref.ref_lut_mpgemm_matmul(a, qw, table_quant="per_row")
    o2 = ref.ref_lut_mpgemm_matmul(a, qcw, table_quant="per_row")
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_cw_bytes_accounting():
    """CW store at W2/K=2 is exactly 1 byte/weight (4x packed, 2x smaller
    than bf16)."""
    w = jnp.asarray(np.random.default_rng(1).normal(size=(128, 256)),
                    jnp.float32)
    qw = Q.quantize(w, 2, k_group=2)
    qcw = Q.to_cw_format(qw)
    assert qcw.cw.size == w.size  # [K, N] int8
    assert qw.packed.size * 4 == w.size  # 2 bits/weight


def test_flash_decode_matches_chunked_8dev():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.distributed.sharding import AxisPlan, plan_scope
    from repro.models import api
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = registry.get_reduced("qwen2-72b").replace(activation_dtype=jnp.float32)
    params = api.init_params(jax.random.key(0), cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = AxisPlan(mesh=mesh, batch=("data",), fsdp=None)
    b, s_cache = 4, 32  # 32 % 4 == 0 -> flash path eligible
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (b, 9)), jnp.int32)
    caches = api.init_cache(cfg, b, s_cache, dtype=jnp.float32)
    _, caches, _ = api.forward(params, {"tokens": toks[:, :8]}, cfg,
                               caches=caches, cache_pos=0)
    # no-plan decode (chunked path)
    lg_ref, _, _ = api.forward(params, {"tokens": toks[:, 8:]}, cfg,
                               caches=caches, cache_pos=8)
    # plan decode (flash_decode_shardmap path)
    def fn(params, caches, t):
        with plan_scope(plan):
            return api.forward(params, {"tokens": t}, cfg, caches=caches,
                               cache_pos=8)[0]
    lg = jax.jit(fn)(params, caches, toks[:, 8:])
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=2e-3, atol=2e-3)
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_moe_shardmap_matches_global_8dev():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.distributed.sharding import AxisPlan, plan_scope
    from repro.models import api
    from repro.models.moe import moe_mlp_apply

    # dropless capacity so both dispatch semantics agree exactly
    cfg = registry.get_reduced("olmoe-1b-7b").replace(
        activation_dtype=jnp.float32, capacity_factor=64.0)
    params = api.init_params(jax.random.key(0), cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = AxisPlan(mesh=mesh, batch=("data",), fsdp="data")
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, cfg.d_model)),
                    jnp.float32) * 0.3
    moe_p = jax.tree.map(lambda p: p[0], params["layers"])["moe"]
    y_ref, aux_ref = moe_mlp_apply(moe_p, x, cfg, None)

    def fn(p, x):
        with plan_scope(plan):
            return moe_mlp_apply(p, x, cfg, None)
    y, aux = jax.jit(fn)(moe_p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(float(aux["lb_loss"]), float(aux_ref["lb_loss"]),
                               rtol=1e-4)
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_hlo_cost_loop_awareness():
    """The roofline cost walker multiplies while bodies by trip counts."""
    from repro.roofline import hlo_cost

    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, w).compile()
    cost = hlo_cost.analyze_text(c.as_text())
    assert cost.flops == 8 * 2 * 128 ** 3  # 8 iterations, not 1
