"""Cache-layout contracts for the block-paged pool: axis discovery
(batch/sequence) with keyed-path errors, slot-view round-trips across every
cache family layout, and the paged gather/scatter pool views."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api, kvcache

# hypothesis drives the round-trip property when available (CI installs
# requirements.txt); otherwise a fixed parametrization covers the same
# layouts so the contract never goes untested
try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _cfg(arch):
    return registry.get_reduced(arch).replace(activation_dtype=jnp.float32,
                                              quant=None)


# ---------------------------------------------------------------------------
# axis discovery: keyed-path errors
# ---------------------------------------------------------------------------

def test_batch_axes_error_names_leaf_and_shapes():
    """An ambiguous probe pair must say WHICH leaf and show BOTH shapes —
    the old message had neither, making hybrid-layout bugs undebuggable."""
    a = {"kv": jnp.zeros((2, 1, 8)), "ssm": jnp.zeros((2, 1, 4))}
    b = {"kv": jnp.zeros((2, 2, 8)), "ssm": jnp.zeros((3, 2, 4))}  # 2 diffs
    with pytest.raises(ValueError) as ei:
        kvcache.batch_axes(a, b)
    msg = str(ei.value)
    assert "'ssm'" in msg.replace('["ssm"]', "'ssm'")  # key path named
    assert "(2, 1, 4)" in msg and "(3, 2, 4)" in msg   # both probe shapes
    assert "2 dims" in msg


def test_batch_axes_error_on_zero_diffs():
    a = {"x": jnp.zeros((2, 4))}
    with pytest.raises(ValueError, match="0 dims"):
        kvcache.batch_axes(a, a)


def test_seq_axes_zero_diffs_means_unpaged():
    """Equal shapes across s_cache probes -> -1 (O(1)-per-slot state)."""
    a = {"conv": jnp.zeros((2, 1, 3, 8)), "kv": jnp.zeros((2, 1, 16, 4))}
    b = {"conv": jnp.zeros((2, 1, 3, 8)), "kv": jnp.zeros((2, 1, 32, 4))}
    ax = kvcache.seq_axes(a, b)
    assert ax == {"conv": -1, "kv": 2}


def test_seq_axes_error_keyed():
    a = {"kv": jnp.zeros((1, 16, 16))}
    b = {"kv": jnp.zeros((1, 32, 32))}
    with pytest.raises(ValueError, match=r"kv.*\(1, 16, 16\).*\(1, 32, 32\)"):
        kvcache.seq_axes(a, b)


def test_zamba2_hybrid_layout_axes():
    """zamba2 hybrid: mamba leaves [n_groups, attn_every, B, ...] carry
    batch at axis 2 and no sequence axis; shared-attn kv is
    [n_groups, B, S, KV, hd]; the tail stack is [tail_layers, B, ...]."""
    cfg = _cfg("zamba2-7b")
    b1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, 32, dtype=jnp.float32))
    b2 = jax.eval_shape(lambda: api.init_cache(cfg, 2, 32, dtype=jnp.float32))
    s2 = jax.eval_shape(lambda: api.init_cache(cfg, 1, 64, dtype=jnp.float32))
    baxes = kvcache.batch_axes(b1, b2)
    saxes = kvcache.seq_axes(b1, s2)
    assert baxes["kv"] == (1, 1) and saxes["kv"] == (2, 2)
    assert all(ax == 2 for ax in jax.tree.leaves(baxes["mamba"]))
    assert all(ax == 1 for ax in jax.tree.leaves(baxes["tail"]))
    for grp in ("mamba", "tail"):
        assert all(ax == -1 for ax in jax.tree.leaves(saxes[grp]))
    # pooled leaves keep seq adjacent to batch: the engine's pool contract
    checks = jax.tree.map(lambda ba, sa: sa in (-1, ba + 1), baxes, saxes)
    assert all(jax.tree.leaves(checks))


# ---------------------------------------------------------------------------
# slot-view round trip across every cache family layout (hypothesis)
# ---------------------------------------------------------------------------

def _layout(name, b, s):
    if name == "attn":
        return kvcache.attn_cache(2, b, s, 2, 4, jnp.float32)
    if name == "attn_int8":
        return kvcache.attn_cache(2, b, s, 2, 4, "int8")
    if name == "mamba":
        return kvcache.mamba_cache(2, b, 8, 4, 4)
    if name == "mamba2":
        return kvcache.mamba2_cache(2, b, 2, 4, 4, 8, 4)
    if name == "hybrid":
        return api.init_cache(_cfg("zamba2-7b"), b, s, dtype=jnp.float32)
    raise AssertionError(name)


LAYOUTS = ["attn", "attn_int8", "mamba", "mamba2", "hybrid"]


def _check_roundtrip(name, b, i, seed):
    """merge_batch(slice_batch(c, i), i) == c for a random-filled cache."""
    caches = _layout(name, b, 16)
    rng = np.random.default_rng(seed)
    caches = jax.tree.map(
        lambda c: jnp.asarray(
            rng.integers(-50, 50, c.shape).astype(np.float32)).astype(c.dtype),
        caches)
    axes = kvcache.batch_axes(
        jax.eval_shape(lambda: _layout(name, 1, 16)),
        jax.eval_shape(lambda: _layout(name, 2, 16)))
    back = kvcache.merge_batch(caches, kvcache.slice_batch(caches, axes, i),
                               axes, i)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), back, caches)


if HAVE_HYPOTHESIS:
    @given(name=st.sampled_from(LAYOUTS), b=st.integers(2, 4),
           data=st.data())
    def test_merge_slice_roundtrip_identity(name, b, data):
        _check_roundtrip(name, b, data.draw(st.integers(0, b - 1)),
                         data.draw(st.integers(0, 2**31 - 1)))
else:
    @pytest.mark.parametrize("name", LAYOUTS)
    def test_merge_slice_roundtrip_identity(name):
        for b, i, seed in [(2, 0, 0), (3, 2, 1), (4, 1, 7)]:
            _check_roundtrip(name, b, i, seed)


# ---------------------------------------------------------------------------
# cache_len on the clamped / int8 variants
# ---------------------------------------------------------------------------

def test_cache_len_windowed_and_int8():
    assert kvcache.cache_len(kvcache.attn_cache(2, 1, 128, 2, 4)) == 128
    # rolling window clamps the stored capacity
    assert kvcache.cache_len(
        kvcache.attn_cache(2, 1, 128, 2, 4, window=32)) == 32
    c = kvcache.attn_cache(2, 1, 64, 2, 4, "int8", window=16)
    assert kvcache.cache_len(c) == 16
    assert len(c) == 4 and c[0].dtype == jnp.int8


# ---------------------------------------------------------------------------
# paged pool views
# ---------------------------------------------------------------------------

def test_paged_scatter_gather_roundtrip():
    """Values written at logical positions come back at the same positions
    of the gathered view, through an arbitrary block permutation."""
    nb_pool, bs, f = 7, 4, 3
    pool = jnp.zeros((nb_pool, bs, f))
    table = jnp.asarray([[5, 2, 6], [1, 4, 3]], jnp.int32)  # [B=2, nb=3]
    pos = jnp.asarray([[0, 5, 11], [3, 4, 10]], jnp.int32)
    vals = jnp.arange(2 * 3 * f, dtype=jnp.float32).reshape(2, 3, f) + 1
    pool = kvcache.paged_scatter(pool, vals, table, pos)
    view = kvcache.paged_gather(pool, table)
    assert view.shape == (2, 12, f)
    for i in range(2):
        for j in range(3):
            np.testing.assert_array_equal(
                np.asarray(view[i, int(pos[i, j])]), np.asarray(vals[i, j]))


def test_paged_scatter_oob_goes_to_null_block():
    """Positions past the table's reach must land in the null block, NOT
    alias the last real block via index clamping (padded prefill tails)."""
    pool = jnp.zeros((4, 2, 1))
    table = jnp.asarray([[3, 2]], jnp.int32)        # reach = 4 positions
    pos = jnp.asarray([[1, 4, 7]], jnp.int32)       # 4 and 7 are OOB
    vals = jnp.ones((1, 3, 1))
    out = kvcache.paged_scatter(pool, vals, table, pos)
    assert float(out[3, 1, 0]) == 1.0               # in-range write landed
    assert not np.asarray(out[2]).any()             # real blocks untouched
    assert np.asarray(out[0]).any()                 # junk absorbed by null


def test_null_block_rows_share_storage_semantics():
    """An all-null table row gathers a view made entirely of block 0 — the
    masked-softmax guarantee (exactly-zero probs beyond valid length) is
    what makes reading it safe; here we just pin the routing."""
    pool = jnp.arange(3 * 2 * 1, dtype=jnp.float32).reshape(3, 2, 1)
    view = kvcache.paged_gather(pool, jnp.zeros((1, 3), jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(view[0]).ravel(),
        np.tile(np.asarray(pool[0]).ravel(), 3))
