"""Collective-layer tests.

  * shard_map psum / all-gather parity against the single-device reference
    on 8 forced host devices (the primitive pattern TP decode relies on:
    row-parallel partial sums -> one psum per layer);
  * sequence-parallel scatter/gather round trip (collectives.sp_*);
  * AxisPlan.resolve / axis_size unit behaviour;
  * param_spec_tree keyed error on unmatched leaves;
  * resolve_physical_spec divisibility + packed bit-group granularity —
    deterministic sweeps plus hypothesis properties (every sharded dim
    divides; a packed byte-dim shard never splits a bit-group).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.distributed import sharding as SH

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # forced host devices exist only on the CPU backend; pinning it
    # also skips the accelerator-plugin probe (a sleep-poll loop that
    # starves 1-cpu boxes)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# subprocess: collective parity on 8 devices
# ---------------------------------------------------------------------------

def test_psum_allgather_parity_8dev():
    """Row-parallel matmul with a psum reduction and a sharded all-gather
    both reproduce the dense single-device result bit-for-bit in f32."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed._compat import make_mesh, shard_map

    mesh = make_mesh((8,), ("model",))
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (4, 64))        # [M, K]
    w = jax.random.normal(k2, (64, 32))       # [K, N]
    want = np.asarray(x @ w)

    # row-parallel: K sharded, each device holds x[:, k/8] @ w[k/8, :]
    # partial sums -> ONE psum yields the full product (TP layer pattern)
    def rowpar(xs, ws):
        return jax.lax.psum(xs @ ws, "model")

    got = shard_map(rowpar, mesh=mesh,
                    in_specs=(P(None, "model"), P("model", None)),
                    out_specs=P())(x, w)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)

    # column-parallel: N sharded, all-gather reassembles the output
    def colpar(xs, ws):
        y = xs @ ws                            # [M, N/8]
        return jax.lax.all_gather(y, "model", axis=1, tiled=True)

    got2 = shard_map(colpar, mesh=mesh,
                     in_specs=(P(), P(None, "model")), out_specs=P())(x, w)
    np.testing.assert_allclose(np.asarray(got2), want, rtol=1e-5, atol=1e-5)
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_sp_scatter_gather_roundtrip_8dev():
    """sp_scatter shards the sequence dim over data; sp_gather restores a
    replicated activation with identical values."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.sharding import AxisPlan, plan_scope
    from repro.distributed.collectives import sp_gather, sp_scatter
    from repro.distributed._compat import make_mesh

    mesh = make_mesh((8,), ("data",))
    plan = AxisPlan(mesh=mesh, batch=("data",), model=None, seq="data")
    x = jax.random.normal(jax.random.key(0), (8, 16, 4))

    def f(x):
        with plan_scope(plan):
            y = sp_scatter(x)
            return sp_gather(y * 2.0)

    got = jax.jit(f)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) * 2.0,
                               rtol=1e-6, atol=1e-6)
    # outside a plan both are identity
    assert sp_scatter(x) is x and sp_gather(x) is x
    print("OK")
    """
    assert "OK" in _run_sub(code)


# ---------------------------------------------------------------------------
# in-process: AxisPlan / rule plumbing
# ---------------------------------------------------------------------------

def _plan_1x1():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return SH.AxisPlan(mesh=mesh, batch=("data",), fsdp="data")


def test_axis_plan_resolve():
    plan = _plan_1x1()
    assert plan.resolve(None) is None
    assert plan.resolve("batch") == "data"      # single-axis batch unwraps
    assert plan.resolve("model") == "model"
    assert plan.resolve("fsdp") == "data"
    assert plan.resolve("seq") is None and plan.resolve("stage") is None
    multi = SH.AxisPlan(mesh=plan.mesh, batch=("pod", "data"))
    assert multi.resolve("batch") == ("pod", "data")
    assert plan.axis_size("model") == 1 and plan.axis_size(None) == 1


def test_param_spec_tree_unmatched_leaf_raises():
    params = {"layers": {"mystery_block": {"theta": jnp.zeros((4, 4))}}}
    with pytest.raises(ValueError, match="mystery_block.*theta"):
        SH.param_spec_tree(params)


def test_quantized_leaf_paths_match_rules():
    """QuantizedWeight flattens with named children, so packed rules fire."""
    from repro.core import quantize as Q
    qw = Q.quantize(jnp.ones((8, 16)), 2, k_group=4)
    specs = SH.param_spec_tree({"layers": {"attn": {"wq": {"qw": qw}}}})
    got = specs["layers"]["attn"]["wq"]["qw"]
    assert got.packed == ("model", None)        # column-parallel: shard N
    assert got.scale == ("model",)
    specs = SH.param_spec_tree({"layers": {"attn": {"wo": {"qw": qw}}}})
    got = specs["layers"]["attn"]["wo"]["qw"]
    assert got.packed == (None, "model")        # row-parallel: shard bytes
    assert got.scale == (None,)


# ---------------------------------------------------------------------------
# resolve_physical_spec: divisibility + packed-group granularity
# ---------------------------------------------------------------------------

AXES = {"data": 2, "model": 4, "pod": 2}


def test_physical_spec_divisibility_sweep():
    # every dim either divides its axis or falls back to replication
    spec = SH.resolve_physical_spec((6, 10), ("data", "model"), AXES)
    assert spec == ("data", None)               # 10 % 4 != 0
    spec = SH.resolve_physical_spec((8, 12), ("data", "model"), AXES)
    assert spec == ("data", "model")
    # tuple axis (pod+data batch): product size must divide
    spec = SH.resolve_physical_spec((8,), (("pod", "data"),), AXES)
    assert spec == (("pod", "data"),)
    spec = SH.resolve_physical_spec((6,), (("pod", "data"),), AXES)
    assert spec == (None,)


def test_physical_spec_packed_granularity():
    """A byte-dim shard that would split a bit-group must replicate.

    W4/k_group=4: one group = 4 planes * 4 weights = 16 bits = 2 bytes.
    K=32 -> 16 bytes -> 4 bytes/shard over model(4): aligned, shards.
    K=8  ->  4 bytes -> 1 byte/shard:  splits a group, replicates.
    """
    ok = SH.resolve_physical_spec((8, 16), (None, "model"), AXES,
                                  last_dim_align=2)
    assert ok == (None, "model")
    bad = SH.resolve_physical_spec((8, 4), (None, "model"), AXES,
                                   last_dim_align=2)
    assert bad == (None, None)


def test_packed_group_bytes_metadata():
    from repro.core import quantize as Q
    qw = Q.quantize(jnp.ones((8, 32)), 4, k_group=4)   # 4 planes
    assert SH.packed_group_bytes(qw) == 2              # 16 bits per group
    qw2 = Q.quantize(jnp.ones((8, 32)), 2, k_group=4)  # 2 planes
    assert SH.packed_group_bytes(qw2) == 1


def test_named_sharding_respects_group_boundaries():
    """End to end: a row-parallel packed weight whose per-shard byte extent
    would split a group is replicated by named_sharding_tree."""
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import quantize as Q
    from repro.distributed.sharding import AxisPlan, named_sharding_tree
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = AxisPlan(mesh=mesh, batch=("data",), fsdp=None)
    aligned = {"mlp": {"down": {"qw": Q.quantize(jnp.ones((8, 32)), 4)}}}
    sh = named_sharding_tree(aligned, plan)
    assert sh["mlp"]["down"]["qw"].packed.spec == P(None, "model"), sh
    split = {"mlp": {"down": {"qw": Q.quantize(jnp.ones((8, 8)), 4)}}}
    sh = named_sharding_tree(split, plan)
    assert sh["mlp"]["down"]["qw"].packed.spec == P(None, None), sh
    print("OK")
    """
    assert "OK" in _run_sub(code)


# ---------------------------------------------------------------------------
# hypothesis properties (CI installs hypothesis; skipped when absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYP = True
except ImportError:
    HAS_HYP = False

if HAS_HYP:
    settings.register_profile("ci", max_examples=50, deadline=None)
    settings.load_profile("ci")

    dims_st = st.lists(st.integers(1, 4096), min_size=1, max_size=4)
    axes_st = st.lists(
        st.sampled_from([None, "data", "model", ("pod", "data")]),
        min_size=1, max_size=4)
    sizes_st = st.fixed_dictionaries({
        "data": st.sampled_from([1, 2, 4, 8]),
        "model": st.sampled_from([1, 2, 4, 8]),
        "pod": st.sampled_from([1, 2])})
    align_st = st.sampled_from([1, 2, 3, 4, 8])

    @given(dims=dims_st, axes=axes_st, sizes=sizes_st, align=align_st)
    def test_resolved_spec_always_divides(dims, axes, sizes, align):
        """Property: whatever the rule proposes, every dim the resolved
        spec shards divides exactly by its mesh-axis size, and a sharded
        final dim of a packed plane keeps whole bit-groups per shard."""
        axes = (axes + [None] * len(dims))[:len(dims)]
        spec = SH.resolve_physical_spec(tuple(dims), tuple(axes), sizes,
                                        last_dim_align=align)
        assert len(spec) == len(dims)
        for i, (dim, ax) in enumerate(zip(dims, spec)):
            if ax is None:
                continue
            size = (sizes[ax] if isinstance(ax, str)
                    else int(np.prod([sizes[a] for a in ax])))
            assert dim % size == 0
            if i == len(dims) - 1:
                assert (dim // size) % align == 0

    @given(n=st.sampled_from([8, 16, 64]),
           k=st.sampled_from([16, 32, 64, 128]),
           bits=st.sampled_from([1, 2, 3, 4]),
           mp=st.sampled_from([2, 4, 8]))
    def test_packed_shard_never_splits_group(n, k, bits, mp):
        """Property over real packed weights: the row-parallel byte-dim
        sharding a plan resolves always lands on group boundaries."""
        from repro.core import quantize as Q
        qw = Q.quantize(jnp.ones((n, k)), bits, k_group=4)
        gb = SH.packed_group_bytes(qw)
        sizes = {"model": mp, "data": 1}
        spec = SH.resolve_physical_spec(
            qw.packed.shape, (None, "model"), sizes, last_dim_align=gb)
        if spec[1] is not None:
            assert (qw.packed.shape[1] // mp) % gb == 0
