"""Tuning-cache durability + autotuner round-trip tests (no hypothesis
needed — the property-based layer lives in test_autotune_properties.py).

  * durability — corrupt / truncated / version-mismatched cache files warn
    and degrade to heuristic dispatch; a foreign-backend cache is kept but
    re-validated at every lookup; concurrent writers never leave a torn
    file (atomic-rename saves);
  * round trip — tune -> save -> fresh load reproduces the identical
    dispatch decision, and ``fusion="tuned"`` is numerically bit-identical
    to ``fusion="auto"`` on the per_row int8 path.
"""

import json
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.autotune import TunedConfig, TuningCache
from repro.core.quantize import quantize
from repro.kernels import ops


def test_candidate_configs_heuristic_first():
    """Candidate 0 is always the heuristic pick; all candidates are valid
    (positive byte-aligned blocks, real fusion modes)."""
    for (m, n, g, kg, planes) in [(4, 512, 16, 4, 2), (16, 256, 7, 3, 1),
                                  (64, 2048, 256, 8, 3)]:
        cands = autotune.candidate_configs(m, n, g, kg, planes)
        assert cands[0].source == "heuristic"
        assert all(c.source == "measured" for c in cands[1:])
        for c in cands:
            assert c.fusion in ("fused", "staged")
            assert c.block_m >= 1 and c.block_n >= 1 and c.block_g >= 1
            assert (c.block_g * planes * kg) % 8 == 0


# ---------------------------------------------------------------------------
# durability
# ---------------------------------------------------------------------------

def test_corrupt_cache_warns_and_degrades(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text("{garbage not json")
    with pytest.warns(UserWarning, match="unreadable"):
        cache = TuningCache(str(p))
    assert len(cache) == 0 and not cache.foreign


def test_truncated_cache_warns_and_degrades(tmp_path):
    good = tmp_path / "good.json"
    cache = TuningCache(str(good))
    cache.put(autotune.shape_key(4, 512, 16, 4, 2),
              TunedConfig("fused", 8, 256, 16))
    cache.save()
    text = good.read_text()
    trunc = tmp_path / "trunc.json"
    trunc.write_text(text[: len(text) // 2])
    with pytest.warns(UserWarning, match="unreadable"):
        reloaded = TuningCache(str(trunc))
    assert len(reloaded) == 0


def test_version_mismatch_warns_and_degrades(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({
        "version": 99, "backend": "cpu", "jax_version": jax.__version__,
        "entries": {"m4.n512.g16.kg4.w2.f32.tqper_row":
                    TunedConfig("fused", 8, 256, 16).as_dict()}}))
    with pytest.warns(UserWarning, match="unknown format"):
        cache = TuningCache(str(p))
    assert len(cache) == 0


def test_foreign_backend_kept_but_sanitized(tmp_path):
    """A cache tuned on another backend warns, keeps entries, and every
    lookup re-validates — an absurd block shape cannot reach the kernels."""
    p = tmp_path / "cache.json"
    key = autotune.shape_key(4, 512, 16, 4, 2)
    p.write_text(json.dumps({
        "version": autotune.CACHE_FORMAT_VERSION,
        "backend": "tpu", "jax_version": "9.9.9",
        "entries": {key: TunedConfig("fused", 4096, 1 << 20, 999).as_dict()}}))
    with pytest.warns(UserWarning, match="re-validated"):
        autotune.configure(str(p))
    try:
        assert autotune.get_active().foreign
        tc = autotune.lookup_tuned(4, 512, 16, 4, 2)
        assert tc is not None
        assert tc.block_m <= 8 and tc.block_n <= 512
        assert (tc.block_g * 2 * 4) % 8 == 0
    finally:
        autotune.deactivate()


def test_malformed_entries_skipped_rest_kept(tmp_path):
    p = tmp_path / "cache.json"
    good_key = autotune.shape_key(4, 512, 16, 4, 2)
    p.write_text(json.dumps({
        "version": autotune.CACHE_FORMAT_VERSION,
        "backend": jax.default_backend(), "jax_version": jax.__version__,
        "entries": {
            good_key: TunedConfig("fused", 8, 256, 16).as_dict(),
            "bad-entry-1": "not a dict",
            "bad-entry-2": {"fusion": "fused", "block_m": "not-an-int",
                            "block_n": 1, "block_g": 1},
        }}))
    cache = TuningCache(str(p))
    assert len(cache) == 1 and cache.lookup(good_key) is not None


def test_concurrent_writers_never_tear_the_file(tmp_path):
    """N threads hammering save() on one path: every interleaved read must
    parse (os.replace is atomic), and the final file is a valid cache."""
    p = str(tmp_path / "cache.json")
    errors = []

    def writer(tid):
        try:
            cache = TuningCache(p, backend="cpu")
            for i in range(20):
                cache.put(f"m{tid}.n{i}.g1.kg4.w2.f32.tqper_row",
                          TunedConfig("staged", 8, 128, 8, steady_ms=i))
                cache.save()
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    def reader():
        import os
        for _ in range(200):
            if not os.path.exists(p):
                continue
            try:
                with open(p) as f:
                    json.load(f)  # a torn write would raise here
            except json.JSONDecodeError as e:  # pragma: no cover
                errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    threads.append(threading.Thread(target=reader))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    final = TuningCache(p, backend="cpu")
    assert len(final) > 0


# ---------------------------------------------------------------------------
# round trip: tune -> persist -> reload -> identical dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_qw():
    w = jax.random.normal(jax.random.key(7), (128, 32))
    return quantize(w, 2, k_group=4)


def test_tune_roundtrip_identical_dispatch(tmp_path, tiny_qw):
    qw, m = tiny_qw, 4
    cache = TuningCache(str(tmp_path / "cache.json"))
    best, measured = autotune.tune_mpgemm(m, qw, cache=cache, repeats=1,
                                          max_candidates=2)
    assert best.source == "measured" and best.steady_ms > 0
    assert best.compile_ms > 0  # compile/steady recorded separately
    # the heuristic is candidate 0 of the same measurement pass, so the
    # winner can only match or beat it
    assert best.steady_ms <= best.heuristic_ms + 1e-9
    cache.save()

    autotune.configure(cache.path)
    try:
        d1 = ops.resolve_dispatch(m, qw.n, qw.g, qw.k_group, qw.num_planes,
                                  fusion="tuned")
    finally:
        autotune.deactivate()
    assert d1 == (best.fusion, best.block_m, best.block_n, best.block_g)

    # fresh process simulation: reload from disk, decision is identical
    autotune.configure(cache.path)
    try:
        d2 = ops.resolve_dispatch(m, qw.n, qw.g, qw.k_group, qw.num_planes,
                                  fusion="tuned")
    finally:
        autotune.deactivate()
    assert d2 == d1


def test_tuned_numerics_match_auto(tmp_path, tiny_qw):
    """fusion="tuned" (cache hit with non-default blocks) is bit-identical
    to fusion="auto" on the per_row int8 path."""
    qw, m = tiny_qw, 4
    x = jax.random.normal(jax.random.key(3), (m, qw.k_total), jnp.float32)
    ref = ops.lut_mpgemm(x, qw, fusion="auto", interpret=True)
    cache = autotune.configure(None)
    try:
        key = autotune.shape_key(m, qw.n, qw.g, qw.k_group, qw.num_planes)
        cache.put(key, TunedConfig("staged", 8, 64, 4))
        out = ops.lut_mpgemm(x, qw, fusion="tuned", interpret=True)
    finally:
        autotune.deactivate()
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_tuned_without_cache_falls_back_to_auto(tiny_qw):
    qw, m = tiny_qw, 4
    autotune.deactivate()
    want = ops.resolve_dispatch(m, qw.n, qw.g, qw.k_group, qw.num_planes,
                                fusion="auto")
    got = ops.resolve_dispatch(m, qw.n, qw.g, qw.k_group, qw.num_planes,
                               fusion="tuned")
    assert got == want
