"""Decoding-mode zoo tests: beam search + bit-plane self-speculation on the
scan engine, plane-sliced draft views, and the sampler fast paths.

The load-bearing invariants:
  * greedy self-speculation is BIT-EXACT with plain greedy decode (the
    verify forward's position-0 logits are the s=1 forward's logits, and
    greedy accept/replace reduces to raw-logit argmax agreement);
  * width-1 beam search IS greedy decode;
  * a mixed pool (normal + beam + spec slots in one jitted scan) gives
    every request the same tokens as a homogeneous pool would;
  * paged beam fan-out shares immutable prefix blocks by reference and
    never aliases mutable (post-divergence) blocks between hypotheses;
  * the plane-sliced draft view reuses the packed buffers (zero extra
    weight HBM) and dequantizes to exactly the top-plane reconstruction.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import quantize as Q
from repro.core import reinterpret
from repro.models import api
from repro.models.quantized import extra_hbm_bytes, plane_sliced_params
from repro.serving import decoding
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import mask_logits, sample


def _cfg():
    cfg = registry.get_reduced("tinyllama-1.1b").replace(
        activation_dtype=jnp.float32)
    # packed store pinned: the spec draft is a plane slice of the packed
    # buffers; float LM head so draft and target share the readout exactly
    return cfg.with_quant(mpgemm_mode="lut_xla", weight_bits=4,
                          store="packed", skip="lm_head")


@pytest.fixture(scope="module")
def tl():
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    return cfg, params


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)),
                         dtype=np.int32) for i in range(n)]


def _run(cfg, params, prompts, n_new, *, decoding_str="greedy",
         engine_kw=None, req_kw=None):
    kw = dict(max_batch=2, max_seq=64, decode_chunk=4, prefill_chunk=4)
    kw.update(engine_kw or {})
    eng = ServingEngine(cfg, params, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=n_new,
                    decoding=decoding_str, **(req_kw or {}))
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    return eng, reqs


# ---------------------------------------------------------------------------
# self-speculation: bit-exactness + stats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft_planes", [1, 2, 4])
def test_spec_greedy_bit_exact_dense(tl, draft_planes):
    """Greedy spec == plain greedy, token for token, for a real sliced
    draft (1/2 planes) AND the accept-everything draft (all 4 planes,
    draft == target)."""
    cfg, params = tl
    prompts = _prompts(cfg, 3)
    _, g_reqs = _run(cfg, params, prompts, 10)
    _, s_reqs = _run(cfg, params, prompts, 10,
                     decoding_str=f"spec:draft{draft_planes}b",
                     engine_kw=dict(spec_k=4,
                                    spec_draft_planes=draft_planes))
    for g, s in zip(g_reqs, s_reqs):
        assert s.done and s.output == g.output
        assert s.spec_stats is not None
        assert s.spec_stats["verify_steps"] > 0
        assert 0 <= s.spec_stats["accepted_draft_tokens"]


def test_spec_accept_all_saturates(tl):
    """draft == target (all planes kept): every draft token is accepted, so
    each verify round emits K+1 tokens until the budget clips."""
    cfg, params = tl
    eng, reqs = _run(cfg, params, _prompts(cfg, 2), 10,
                     decoding_str="spec:draft4b",
                     engine_kw=dict(spec_k=4, spec_draft_planes=4))
    sp = eng.stats()["spec"]
    # draft == target means every comparison agrees; only the budget clip
    # on the final round can shave the counted mean below K=4
    assert sp["mean_accepted_per_step"] >= 3.0
    assert sp["mean_emitted_per_step"] == pytest.approx(
        sp["mean_accepted_per_step"] + 1.0)
    assert sp["draft_extra_hbm_bytes"] == 0


def test_spec_greedy_bit_exact_paged(tl):
    cfg, params = tl
    prompts = _prompts(cfg, 3, seed=1)
    paged = dict(cache_block_size=8, num_cache_blocks=17)
    _, g_reqs = _run(cfg, params, prompts, 8, engine_kw=paged)
    _, s_reqs = _run(cfg, params, prompts, 8, decoding_str="spec:draft2b",
                     engine_kw=dict(paged, spec_k=3, spec_draft_planes=2))
    for g, s in zip(g_reqs, s_reqs):
        assert s.done and s.output == g.output


def test_spec_stochastic_runs_and_counts(tl):
    """Sampling spec slots run the rejection-sampling path: outputs are
    legal tokens, stats stay consistent (accepted <= K per verify step)."""
    cfg, params = tl
    eng, reqs = _run(cfg, params, _prompts(cfg, 2, seed=3), 12,
                     decoding_str="spec:draft2b",
                     engine_kw=dict(spec_k=4, spec_draft_planes=2),
                     req_kw=dict(temperature=0.9, top_k=40, top_p=0.95))
    for r in reqs:
        assert r.done and len(r.output) == 12
        assert all(0 <= t < cfg.vocab_size for t in r.output)
        vs, at = (r.spec_stats["verify_steps"],
                  r.spec_stats["accepted_draft_tokens"])
        assert vs > 0 and 0 <= at <= 4 * vs


# ---------------------------------------------------------------------------
# beam search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_beam_width1_equals_greedy(tl, paged):
    """A width-1 beam maximizes per-step log-prob == greedy argmax."""
    cfg, params = tl
    prompts = _prompts(cfg, 2, seed=2)
    kw = (dict(cache_block_size=8, num_cache_blocks=17) if paged else {})
    _, g_reqs = _run(cfg, params, prompts, 8, engine_kw=kw)
    _, b_reqs = _run(cfg, params, prompts, 8, decoding_str="beam:1",
                     engine_kw=kw)
    for g, b in zip(g_reqs, b_reqs):
        assert b.done and b.output == g.output
        assert b.beams is not None and len(b.beams) == 1
        assert list(b.beams[0][0]) == g.output


@pytest.mark.parametrize("paged", [False, True])
def test_beam_search_hypotheses_ranked(tl, paged):
    cfg, params = tl
    kw = dict(max_batch=3)
    if paged:
        kw.update(cache_block_size=8, num_cache_blocks=25)
    _, reqs = _run(cfg, params, _prompts(cfg, 1, seed=4), 8,
                   decoding_str="beam:3", engine_kw=kw)
    (r,) = reqs
    assert r.done and r.beams is not None and len(r.beams) == 3
    scores = [s for _, s in r.beams]
    assert scores == sorted(scores, reverse=True)  # best first
    assert r.output == list(r.beams[0][0])
    assert all(len(t) <= 8 for t, _ in r.beams)
    # width-3 search explored: hypotheses are not all identical
    assert len({tuple(t) for t, _ in r.beams}) > 1


def test_paged_beam_forks_share_prefix_blocks(tl):
    """PR-7 follow-on: beam members share the immutable prompt-prefix
    blocks BY REFERENCE (refcount, no copy) and own private blocks for
    everything at/after the divergence point — never aliased. Retiring the
    group returns every block."""
    cfg, params = tl
    bs = 4
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=64,
                        decode_chunk=4, prefill_chunk=4,
                        cache_block_size=bs, num_cache_blocks=49)
    plen = 9  # (plen-1)//bs == 2 shared blocks, block 2 is the divergence
    prompt = np.arange(plen, dtype=np.int32) % cfg.vocab_size
    req = Request(uid=0, prompt=prompt, max_new_tokens=6, decoding="beam:3")
    eng.submit(req)
    eng._admit()
    (group,) = eng._beam_groups.values()
    rows = [eng._slot_blocks[s] for s in group["slots"]]
    m_share = (plen - 1) // bs
    lead_row = rows[0]
    for row in rows[1:]:
        assert row[:m_share] == lead_row[:m_share]  # shared by reference
    for bid in lead_row[:m_share]:
        assert eng._alloc.refs[bid] == len(rows)
    # post-divergence blocks: pairwise disjoint across members
    tails = [set(row[m_share:]) for row in rows]
    for i in range(len(tails)):
        for j in range(i + 1, len(tails)):
            assert not (tails[i] & tails[j])
    eng.run_to_completion()
    assert req.done and len(req.beams) == 3
    assert eng._alloc.num_used == 0  # group retirement freed everything


# ---------------------------------------------------------------------------
# mixed pools
# ---------------------------------------------------------------------------

def test_mixed_mode_pool_parity(tl):
    """normal + beam:2 + spec slots decode in ONE scan; every request gets
    exactly the tokens its homogeneous-pool run produces (greedy)."""
    cfg, params = tl
    prompts = _prompts(cfg, 3, seed=5)
    ekw = dict(max_batch=4, spec_k=3, spec_draft_planes=2)
    eng = ServingEngine(cfg, params, max_seq=64, decode_chunk=4,
                        prefill_chunk=4, **ekw)
    reqs = [Request(uid=0, prompt=prompts[0], max_new_tokens=8),
            Request(uid=1, prompt=prompts[1], max_new_tokens=8,
                    decoding="beam:2"),
            Request(uid=2, prompt=prompts[2], max_new_tokens=8,
                    decoding="spec:draft2b")]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()

    _, (solo_n,) = _run(cfg, params, [prompts[0]], 8)
    _, (solo_b,) = _run(cfg, params, [prompts[1]], 8,
                        decoding_str="beam:2", engine_kw=dict(max_batch=2))
    _, (solo_s,) = _run(cfg, params, [prompts[2]], 8,
                        decoding_str="spec:draft2b",
                        engine_kw=dict(max_batch=1, spec_k=3,
                                       spec_draft_planes=2))
    assert reqs[0].output == solo_n.output
    assert reqs[1].output == solo_b.output
    assert [t for t, _ in reqs[1].beams] == [t for t, _ in solo_b.beams]
    assert reqs[2].output == solo_s.output


# ---------------------------------------------------------------------------
# decoding-mode registry
# ---------------------------------------------------------------------------

def test_decoding_parse():
    assert decoding.parse("greedy").kind == decoding.NORMAL
    assert decoding.parse("beam").beam_width == 4
    assert decoding.parse("beam:2").beam_width == 2
    assert decoding.parse("spec").draft_planes == 2
    assert decoding.parse("spec:draft1b").draft_planes == 1
    assert decoding.parse("spec:3").draft_planes == 3
    for bad in ("beam:0", "spec:0b", "greedy:x", "wat", "spec:draftb"):
        with pytest.raises(ValueError):
            decoding.parse(bad)


# ---------------------------------------------------------------------------
# sampler: regression + static-vs-vectorized parity
# ---------------------------------------------------------------------------

def test_sampler_static_topk_oversized_regression():
    """Static-path top_k > vocab must mean 'disabled', not crash (the old
    scalar path fed it straight to lax.top_k)."""
    logits = jax.random.normal(jax.random.key(0), (2, 8))
    big = mask_logits(logits, temperature=1.0, top_k=100)
    off = mask_logits(logits, temperature=1.0, top_k=0)
    np.testing.assert_array_equal(np.asarray(big), np.asarray(off))
    t = sample(jax.random.key(1), logits, temperature=1.0, top_k=100)
    assert np.asarray(t).shape == (2,) and all(0 <= x < 8 for x in t)


@pytest.mark.parametrize("temp,tk,tp", [
    (1.0, 0, 1.0),      # fully disabled (runtime fast path)
    (0.7, 3, 1.0),      # top-k only
    (1.0, 0, 0.7),      # top-p exactly at a cumulative-mass boundary
    (1.3, 2, 0.6),      # both cuts
    (1.0, 99, 1.0),     # oversized k == disabled
])
def test_sampler_static_vs_vectorized_parity(temp, tk, tp):
    """Scalar params and [B]-array params must produce IDENTICAL masked
    logits and samples — including at the top_p boundary where cumulative
    mass hits the cutoff exactly."""
    probs = np.array([0.4, 0.3, 0.2, 0.1])  # cum: .4 .7 .9 1.0 (boundary!)
    logits = jnp.asarray(np.log(probs)[None].repeat(3, 0))
    b = logits.shape[0]
    m_static = mask_logits(logits, temperature=temp, top_k=tk, top_p=tp)
    m_vec = jax.jit(lambda l, t, k, p: mask_logits(
        l, temperature=t, top_k=k, top_p=p))(
        logits, jnp.full(b, temp), jnp.full(b, tk, jnp.int32),
        jnp.full(b, tp))
    np.testing.assert_array_equal(np.asarray(m_static), np.asarray(m_vec))
    key = jax.random.key(42)
    s_static = sample(key, logits, temperature=temp, top_k=tk, top_p=tp)
    s_vec = jax.jit(lambda kk, l, t, k, p: sample(
        kk, l, temperature=t, top_k=k, top_p=p))(
        key, logits, jnp.full(b, temp), jnp.full(b, tk, jnp.int32),
        jnp.full(b, tp))
    np.testing.assert_array_equal(np.asarray(s_static), np.asarray(s_vec))


def test_mask_logits_runtime_fastpath_exact():
    """The lax.cond fast path (no row cuts) must be bitwise identical to
    the full sort path, and mixed rows must still take the full path."""
    logits = jax.random.normal(jax.random.key(5), (2, 16))
    # all-disabled [B] params: fast path == plain temperature scale
    fast = jax.jit(lambda l: mask_logits(
        l, temperature=jnp.full(2, 2.0), top_k=jnp.zeros(2, jnp.int32),
        top_p=jnp.ones(2)))(logits)
    np.testing.assert_array_equal(np.asarray(fast),
                                  np.asarray(logits / 2.0))
    # mixed rows: row0 disabled, row1 cut -> full path for the whole batch;
    # row0's result must STILL equal its solo disabled masking
    mixed = jax.jit(lambda l: mask_logits(
        l, temperature=jnp.full(2, 1.0),
        top_k=jnp.asarray([0, 2], jnp.int32),
        top_p=jnp.asarray([1.0, 0.6])))(logits)
    np.testing.assert_array_equal(np.asarray(mixed[0]),
                                  np.asarray(logits[0]))
    assert np.isneginf(np.asarray(mixed[1])).sum() >= 14 - 2


# ---------------------------------------------------------------------------
# plane-sliced draft views
# ---------------------------------------------------------------------------

def test_plane_slice_dequant_is_top_plane_reconstruction():
    w = jax.random.normal(jax.random.key(2), (8, 16))
    qw = Q.quantize(w, 4, k_group=4)
    sign, idx = qw.sign_idx()
    planes = reinterpret.unfold_group_codes(sign, idx, qw.k_group)
    sigma = 2.0 * planes.astype(jnp.float32) - 1.0  # [N, K, 4]
    for keep in (1, 2, 3):
        view = qw.plane_slice(keep)
        assert view.plane_scales == qw.plane_scales[4 - keep:]
        qp = jnp.einsum(
            "nkb,b->nk", sigma[..., 4 - keep:],
            jnp.asarray(qw.plane_scales[4 - keep:], jnp.float32))
        if qw.zero_prime is not None:
            qp = qp - qw.zero_prime[:, None]
        want = qw.scale[:, None] * qp
        np.testing.assert_allclose(np.asarray(Q.dequantize(view)),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)
        # truncation error bounded by the dropped plane-scale sum
        bound = reinterpret.plane_truncation_bound(qw.plane_scales, keep)
        err = np.abs(np.asarray(Q.dequantize(qw) - Q.dequantize(view)))
        assert (err <= np.asarray(qw.scale)[:, None] * bound + 1e-5).all()


def test_plane_slice_shares_buffers_and_guards():
    w = jax.random.normal(jax.random.key(3), (8, 16))
    qw = Q.quantize(w, 4, k_group=4)
    view = qw.plane_slice(2)
    assert view.packed is qw.packed and view.scale is qw.scale
    assert view.is_plane_sliced and not qw.is_plane_sliced
    assert qw.plane_slice(4) is qw          # keep >= B: the weight itself
    with pytest.raises(ValueError):
        qw.plane_slice(0)
    cw_qw = Q.to_cw_format(qw)
    # CW store bakes every plane into the codeword matrix: not sliceable
    if cw_qw.packed is None:
        with pytest.raises(ValueError):
            cw_qw.plane_slice(2)


def test_plane_sliced_params_zero_extra_hbm(tl):
    cfg, params = tl
    draft = plane_sliced_params(params, 2)
    assert extra_hbm_bytes(draft, params) == 0
    # and the view is NOT the identity: at least one leaf is sliced
    from repro.core.quantize import QuantizedWeight
    leaves = [x for x in jax.tree.leaves(
        draft, is_leaf=lambda n: isinstance(n, QuantizedWeight))
        if isinstance(x, QuantizedWeight)]
    assert leaves and all(x.num_planes == 2 for x in leaves)


def test_pallas_kernels_reject_sliced_views():
    """The Pallas kernels unpack bytes in-kernel with num_planes as the
    field stride — a sliced view would decode garbage; they must refuse."""
    from repro.kernels import ops
    w = jax.random.normal(jax.random.key(4), (16, 32))
    qw = Q.quantize(w, 4, k_group=4)
    view = qw.plane_slice(2)
    x = jnp.ones((2, 32), jnp.float32)
    for fn in (ops.lut_mpgemm, ops.fused_lut_mpgemm, ops.dequant_mpgemm):
        with pytest.raises(NotImplementedError):
            fn(x, view, interpret=True)


# ---------------------------------------------------------------------------
# engine stats hygiene
# ---------------------------------------------------------------------------

def _assert_tree_finite(obj, path="stats"):
    if isinstance(obj, dict):
        for k, v in obj.items():
            _assert_tree_finite(v, f"{path}.{k}")
    elif isinstance(obj, (int, float, np.integer, np.floating)) \
            and not isinstance(obj, bool):
        assert np.isfinite(obj), f"non-finite {path} = {obj!r}"


def test_stats_finite_with_zero_admission_attempts(tl):
    """A fresh engine (no admissions, no decodes) must report finite stats
    — the blocked-admissions rate divides by max(1, attempts)."""
    cfg, params = tl
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        cache_block_size=8, num_cache_blocks=17)
    st = eng.stats()
    assert st["admission_blocked_rate"] == 0.0
    _assert_tree_finite(st)


def test_stats_finite_after_spec_and_beam(tl):
    cfg, params = tl
    eng, _ = _run(cfg, params, _prompts(cfg, 2, seed=6), 6,
                  decoding_str="spec:draft2b",
                  engine_kw=dict(spec_k=2, spec_draft_planes=2))
    _assert_tree_finite(eng.stats())
    eng2, _ = _run(cfg, params, _prompts(cfg, 1, seed=7), 6,
                   decoding_str="beam:2", engine_kw=dict(max_batch=2))
    _assert_tree_finite(eng2.stats())
