"""Fault-tolerance policy + elastic re-mesh tests."""

import numpy as np

from repro.distributed import elastic
from repro.training.fault_tolerance import Action, FaultToleranceManager


def test_heartbeat_failure_detection():
    ft = FaultToleranceManager(4, heartbeat_timeout=10.0)
    now = 1000.0
    for i in range(4):
        ft.heartbeat(i, now=now)
    assert ft.decide(now=now + 5) == Action.CONTINUE
    ft.heartbeat(0, now=now + 20)
    ft.heartbeat(1, now=now + 20)
    ft.heartbeat(2, now=now + 20)
    # host 3 silent past the deadline
    assert 3 in ft.dead_hosts(now=now + 20)
    assert ft.decide(now=now + 20) == Action.ELASTIC_DOWNSIZE


def test_spare_replacement_preferred():
    ft = FaultToleranceManager(4, n_spares=1, heartbeat_timeout=10.0)
    now = 0.0
    for i in range(4):
        ft.heartbeat(i, now=now)
    ft.mark_failed(2)
    assert ft.decide(now=now) == Action.REPLACE_WITH_SPARE
    ft.mark_failed(1)  # second failure: no spares left
    assert ft.decide(now=now) == Action.ELASTIC_DOWNSIZE


def test_straggler_detection_patience():
    ft = FaultToleranceManager(4, straggler_factor=1.5, patience=3)
    for step in range(5):
        for i in range(4):
            ft.heartbeat(i, step_duration=10.0 if i == 2 else 1.0)
        slow = ft.stragglers()
    assert slow == [2]
    assert ft.decide() == Action.RESUME_SAME_MESH  # no spares: reschedule


def test_elastic_downsize_plan():
    # 4x4 mesh (data, model): failing device 5 kills data-row 1
    d = elastic.plan_downsize((4, 4), ("data", "model"), [5])
    assert d.old_data == 4
    assert d.dropped_rows == (1,)
    assert d.new_data == 2  # 3 intact rows -> floor pow2 = 2
    assert d.microbatch_scale == 2  # global batch preserved by 2x accumulation


def test_elastic_downsize_multi_pod_axes():
    # (pod, data, model) = (2, 4, 2): device index 9 = pod1,data0,model1
    d = elastic.plan_downsize((2, 4, 2), ("pod", "data", "model"), [9])
    assert d.dropped_rows == (0,)
    assert d.new_data == 2


def test_elastic_no_failures_is_identity():
    d = elastic.plan_downsize((8, 2), ("data", "model"), [])
    assert d.new_data == 8 and d.microbatch_scale == 1
