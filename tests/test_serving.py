"""Serving engine tests: continuous batching correctness, sampler."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import sample


def _engine(arch="tinyllama-1.1b", quantized=True, max_batch=3, max_seq=64):
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    params = api.init_params(jax.random.key(0), cfg,
                             serve_quantized=quantized)
    if not quantized:
        cfg = cfg.replace(quant=None)
    return cfg, ServingEngine(cfg, params, max_batch=max_batch,
                              max_seq=max_seq)


def _reference_generate(cfg, params, prompt, n_new):
    """Sequential greedy decode, no batching — ground truth."""
    caches = api.init_cache(cfg, 1, 64, dtype=jnp.float32)
    toks = jnp.asarray(prompt[None], jnp.int32)
    logits, caches, _ = api.forward(params, {"tokens": toks}, cfg,
                                    caches=caches, cache_pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = toks.shape[1]
    for _ in range(n_new - 1):
        t = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches, _ = api.forward(params, {"tokens": t}, cfg,
                                        caches=caches, cache_pos=pos)
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


def test_continuous_batching_matches_sequential():
    """Tokens from the batched engine == unbatched greedy decode."""
    cfg, eng = _engine(max_batch=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(3)]  # 3 requests > 2 slots: forces refill
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.output) == 5
        want = _reference_generate(cfg, eng.params, p, 5)
        assert r.output == want, (r.uid, r.output, want)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b"])
def test_serving_ssm(arch):
    cfg, eng = _engine(arch, max_batch=2)
    rng = np.random.default_rng(1)
    req = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 6,
                                             dtype=np.int32),
                  max_new_tokens=4)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done and len(req.output) == 4


def test_sampler_modes():
    key = jax.random.key(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(key, logits)[0]) == 1  # greedy
    t = sample(key, logits, temperature=1.0, top_k=2)
    assert int(t[0]) in (1, 2)
    t = sample(key, logits, temperature=1.0, top_p=0.5)
    assert int(t[0]) == 1  # p(1) ~ 0.96 > 0.5 -> only candidate


def test_engine_respects_max_seq():
    cfg, eng = _engine(max_batch=1, max_seq=16)
    req = Request(uid=0, prompt=np.arange(8, dtype=np.int32) % cfg.vocab_size,
                  max_new_tokens=100)  # would overflow the cache
    eng.submit(req)
    eng.run_to_completion()
    assert req.done
    assert len(req.output) <= 16 - 8 + 1
