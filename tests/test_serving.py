"""Serving engine tests: device-resident continuous batching, chunked
prefill/decode parity, per-slot sampling, cache slot views."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api, kvcache
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import sample


def _cfg(arch="tinyllama-1.1b", quantized=True):
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    if not quantized:
        cfg = cfg.replace(quant=None)
    return cfg


@pytest.fixture(scope="module")
def tl():
    """(cfg, quantized serving params) for the dense reduced arch."""
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    return ServingEngine(cfg, params, **kw)


def _reference_generate(cfg, params, prompt, n_new, s_cache=64):
    """Sequential greedy decode, no batching, no padding — ground truth."""
    caches = api.init_cache(cfg, 1, s_cache, dtype=jnp.float32)
    toks = jnp.asarray(np.asarray(prompt)[None], jnp.int32)
    logits, caches, _ = api.forward(params, {"tokens": toks}, cfg,
                                    caches=caches, cache_pos=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = toks.shape[1]
    for _ in range(n_new - 1):
        t = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches, _ = api.forward(params, {"tokens": t}, cfg,
                                        caches=caches, cache_pos=pos)
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# golden parity + chunked decode
# ---------------------------------------------------------------------------

def test_golden_parity_and_chunked_decode(tl):
    """Greedy engine output == sequential reference, for ragged prompt
    lengths with mid-stream retire/refill — and identical whether the decode
    loop syncs every token (decode_chunk=1) or once per 8 tokens."""
    cfg, params = tl
    rng = np.random.default_rng(0)
    plens = [5, 8, 11, 3, 6]          # ragged, 5 requests > 2 slots
    n_new = [4, 6, 3, 5, 4]           # ragged budgets -> mid-stream retire
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in plens]

    def run(decode_chunk):
        eng = _engine(cfg, params, decode_chunk=decode_chunk,
                      prefill_chunk=4)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, n_new))]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return eng, reqs

    eng1, reqs1 = run(1)
    for r, p, n in zip(reqs1, prompts, n_new):
        assert r.done and len(r.output) == n
        want = _reference_generate(cfg, params, p, n)
        assert r.output == want, (r.uid, r.output, want)

    eng8, reqs8 = run(8)
    for r1, r8 in zip(reqs1, reqs8):
        assert r8.done and r8.output == r1.output

    # the device-resident loop syncs once per CHUNK, not once per token
    assert eng1.decode_syncs > eng8.decode_syncs
    # at full occupancy the per-token bound is exactly <= 1/decode_chunk
    # (the ragged workload above idles slots mid-chunk, so assert on a busy
    # one; compiled programs are reused across reset())
    eng8.reset()
    for i in range(2):
        eng8.submit(Request(uid=i, prompt=prompts[i], max_new_tokens=16))
    eng8.run_to_completion()
    assert eng8.stats()["host_syncs_per_token"] <= 1 / 8 + 1e-9


def test_continuous_batching_matches_sequential(tl):
    """Historical regression: batched engine == unbatched greedy decode."""
    cfg, params = tl
    eng = _engine(cfg, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(3)]  # 3 requests > 2 slots: forces refill
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.output) == 5
        want = _reference_generate(cfg, params, p, 5)
        assert r.output == want, (r.uid, r.output, want)


# ---------------------------------------------------------------------------
# per-slot sampling (the old engine hardcoded temperature=0.0 at decode)
# ---------------------------------------------------------------------------

def test_per_slot_sampling_regression(tl):
    """Slots with different sampling params coexist in one pool: the greedy
    slot stays bit-identical to the reference while the temperature>0 slot
    actually samples (the old engine ignored Request.temperature)."""
    cfg, params = tl
    eng = _engine(cfg, params, decode_chunk=4)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 7, dtype=np.int32)
    greedy = Request(uid=0, prompt=prompt, max_new_tokens=8, temperature=0.0)
    hot = Request(uid=1, prompt=prompt, max_new_tokens=8, temperature=1.5,
                  top_k=5)
    eng.submit(greedy)
    eng.submit(hot)
    eng.run_to_completion()
    want = _reference_generate(cfg, params, prompt, 8)
    assert greedy.output == want            # greedy path: bit-identical
    assert len(hot.output) == 8
    assert hot.output != want               # hot path: actually sampled


def test_engine_eos_stopping(tl):
    """On-device EOS: the slot stops at (and includes) the EOS token."""
    cfg, params = tl
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    ref = _reference_generate(cfg, params, prompt, 6)
    eos = ref[2]
    eng = _engine(cfg, params, decode_chunk=4, eos_id=eos)
    req = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done
    want = ref[:ref.index(eos) + 1]
    assert req.output == want


# ---------------------------------------------------------------------------
# admission edges
# ---------------------------------------------------------------------------

def test_admit_truncates_overlong_prompt(tl):
    """len(prompt) > max_seq used to crash _admit; now it truncates to the
    last max_seq - max_new_tokens tokens and still matches the reference."""
    cfg, params = tl
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 100, dtype=np.int32)
    eng = _engine(cfg, params, max_batch=1, max_seq=32, decode_chunk=4,
                  prefill_chunk=8)
    req = Request(uid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done and len(req.output) == 8
    want = _reference_generate(cfg, params, prompt[-24:], 8, s_cache=32)
    assert req.output == want


def test_engine_respects_max_seq(tl):
    cfg, params = tl
    eng = _engine(cfg, params, max_batch=1, max_seq=16)
    req = Request(uid=0, prompt=np.arange(8, dtype=np.int32) % cfg.vocab_size,
                  max_new_tokens=100)  # would overflow the cache
    eng.submit(req)
    eng.run_to_completion()
    assert req.done
    assert len(req.output) <= 16 - 8 + 1


# ---------------------------------------------------------------------------
# SSM / hybrid: chunked prefill must keep recurrent state exact under the
# right-padded fixed-shape tail chunk (token_valid masking)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["falcon-mamba-7b"])
def test_serving_ssm_chunked_prefill_parity(arch):
    cfg = _cfg(arch)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    rng = np.random.default_rng(1)
    # prompt lens 6/9 with prefill_chunk=4: the 5- and 8-token prefills hit
    # a padded tail chunk (valid 1 of 4) — exercises the state masking
    prompts = [rng.integers(0, cfg.vocab_size, p, dtype=np.int32)
               for p in (6, 9, 5)]   # 3 requests > 2 slots: refill too
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        decode_chunk=4, prefill_chunk=4)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.output) == 4
        want = _reference_generate(cfg, params, p, 4)
        assert r.output == want, (r.uid, r.output, want)


# ---------------------------------------------------------------------------
# sampler: vectorized per-slot params
# ---------------------------------------------------------------------------

def test_sampler_modes_scalar():
    key = jax.random.key(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(key, logits)[0]) == 1  # greedy
    t = sample(key, logits, temperature=1.0, top_k=2)
    assert int(t[0]) in (1, 2)
    t = sample(key, logits, temperature=1.0, top_p=0.5)
    assert int(t[0]) == 1  # p(1) ~ 0.96 > 0.5 -> only candidate


def test_sampler_array_matches_scalar():
    """Array-valued params (broadcast) reproduce the static scalar path."""
    key = jax.random.key(7)
    logits = jax.random.normal(jax.random.key(1), (4, 32))
    want = sample(key, logits, temperature=1.0, top_k=3, top_p=0.7)
    got = jax.jit(lambda k, l, t, tk, tp: sample(k, l, temperature=t,
                                                 top_k=tk, top_p=tp))(
        key, logits, jnp.full(4, 1.0), jnp.full(4, 3, jnp.int32),
        jnp.full(4, 0.7))
    assert (np.asarray(want) == np.asarray(got)).all()


def test_sampler_topk_support():
    """top-k never samples outside the k highest logits, per slot."""
    logits = jax.random.normal(jax.random.key(2), (2, 50))
    ks = jnp.asarray([1, 3], jnp.int32)
    topsets = [set(np.argsort(np.asarray(logits[i]))[-int(ks[i]):])
               for i in range(2)]
    fn = jax.jit(lambda k: sample(k, logits, temperature=jnp.full(2, 1.0),
                                  top_k=ks))
    for s in range(25):
        t = np.asarray(fn(jax.random.key(s)))
        assert t[0] in topsets[0] and t[1] in topsets[1]


def test_sampler_topp_mass_cutoff():
    """top-p keeps exactly the smallest prefix of sorted probs reaching the
    mass cutoff; samples never land outside it (per-slot p)."""
    probs = np.array([0.5, 0.3, 0.15, 0.05])
    logits = jnp.asarray(np.log(probs)[None].repeat(2, 0))
    tp = jnp.asarray([0.8, 1.0])  # row0 keeps {0,1}; row1 keeps everything
    fn = jax.jit(lambda k: sample(k, logits, temperature=jnp.full(2, 1.0),
                                  top_p=tp))
    seen1 = set()
    for s in range(40):
        t = np.asarray(fn(jax.random.key(s)))
        assert t[0] in (0, 1)
        seen1.add(int(t[1]))
    assert len(seen1) > 2  # the p=1.0 row is NOT truncated


def test_sampler_temperature_zero_limit():
    """temp->0 converges to argmax; temp==0 is argmax exactly (no PRNG)."""
    logits = jax.random.normal(jax.random.key(3), (3, 16))
    am = np.asarray(jnp.argmax(logits, -1))
    for temps in ([0.0, 0.0, 0.0], [1e-4, 0.0, 1e-4]):
        t = jax.jit(lambda k: sample(k, logits,
                                     temperature=jnp.asarray(temps)))(
            jax.random.key(9))
        assert (np.asarray(t) == am).all()


# ---------------------------------------------------------------------------
# per-slot cache views
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "zamba2-7b"])
def test_kvcache_slot_views_roundtrip(arch):
    """slice/merge of one slot's cache rows is exact and touches only that
    slot — incl. the hybrid layout whose mamba leaves carry batch at axis 2."""
    cfg = _cfg(arch, quantized=False)
    b, s = 3, 16
    axes = kvcache.batch_axes(
        jax.eval_shape(lambda: api.init_cache(cfg, 1, s, dtype=jnp.float32)),
        jax.eval_shape(lambda: api.init_cache(cfg, 2, s, dtype=jnp.float32)))
    caches = api.init_cache(cfg, b, s, dtype=jnp.float32)
    i = 0
    caches = jax.tree.map(
        lambda c: jnp.arange(c.size, dtype=jnp.float32).reshape(c.shape),
        caches)
    sliced = kvcache.slice_batch(caches, axes, 1)
    jax.tree.map(lambda sc, ax: None, sliced, axes)
    back = kvcache.merge_batch(caches, sliced, axes, 1)
    jax.tree.map(lambda a, c: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(c)), back, caches)
    zeroed = kvcache.merge_batch(
        caches, jax.tree.map(jnp.zeros_like, sliced), axes, 1)

    def check(z, c, ax):
        z, c = np.asarray(z), np.asarray(c)
        assert not z.take(1, axis=ax).any()               # slot 1 zeroed
        np.testing.assert_array_equal(z.take(0, axis=ax),  # others intact
                                      c.take(0, axis=ax))
        np.testing.assert_array_equal(z.take(2, axis=ax),
                                      c.take(2, axis=ax))
    jax.tree.map(check, zeroed, caches, axes)
