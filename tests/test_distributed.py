"""Distribution-layer tests.

Sharding-rule unit tests run in-process; anything needing multiple devices
(pjit train step, pipeline parallelism, sharded decode) runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
dry-run owns the 512-device configuration; tests stay small).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.distributed import sharding as SH
from repro.models import api

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # forced host devices exist only on the CPU backend; pinning it
    # also skips the accelerator-plugin probe (a sleep-poll loop that
    # starves 1-cpu boxes)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# in-process: spec rules
# ---------------------------------------------------------------------------

def test_param_spec_rules():
    cfg = registry.get_reduced("tinyllama-1.1b")
    params = api.init_params(jax.random.key(0), cfg)
    specs = SH.param_spec_tree(params)
    # attention qkv column-parallel, o row-parallel, embed vocab-sharded
    assert specs["layers"]["attn"]["wq"]["w"] == (None, "fsdp", "model")
    assert specs["layers"]["attn"]["wo"]["w"] == (None, "model", "fsdp")
    assert specs["embed"]["table"] == ("model", "fsdp")
    assert specs["layers"]["mlp"]["down"]["w"] == (None, "model", "fsdp")
    assert specs["final_norm"]["g"] == (None,)


def test_moe_expert_specs():
    cfg = registry.get_reduced("olmoe-1b-7b")
    params = api.init_params(jax.random.key(0), cfg)
    specs = SH.param_spec_tree(params)
    assert specs["layers"]["moe"]["experts"]["up"] == \
        (None, "expert", "fsdp", None)
    assert specs["layers"]["moe"]["router"]["w"] == (None, None, "expert")


def test_divisibility_fallback_replicates():
    """A dim not divisible by its mesh axis must fall back to replication."""
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import AxisPlan, named_sharding_tree
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = AxisPlan(mesh=mesh, batch=("data",), fsdp="data")
    params = {"attn": {"wq": {"w": jnp.zeros((6, 10))}}}  # 10 % 4 != 0
    sh = named_sharding_tree(params, plan)
    assert sh["attn"]["wq"]["w"].spec == P("data", None), sh
    print("OK")
    """
    assert "OK" in _run_sub(code)


# ---------------------------------------------------------------------------
# subprocess: 8-device pjit train step + sharded decode
# ---------------------------------------------------------------------------

def test_pjit_train_step_8dev():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import registry
    from repro.distributed.sharding import AxisPlan, plan_scope
    from repro.training import optimizer as O
    from repro.training.train_loop import (init_train_state, make_train_step,
                                           train_shardings)
    from repro.training.data import SyntheticLM

    cfg = registry.get_reduced("tinyllama-1.1b").replace(
        activation_dtype=jnp.float32)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = AxisPlan(mesh=mesh, batch=("data",), fsdp="data")
    opt = O.make_optimizer("adamw", lr=3e-3)
    state = init_train_state(jax.random.key(0), cfg, opt)
    sh = train_shardings(state, plan)
    state = jax.tree.map(jax.device_put, state, sh)
    step = make_train_step(cfg, opt)

    def fn(state, batch):
        with plan_scope(plan):
            return step(state, batch)

    data = SyntheticLM(cfg.vocab_size, 4, 16)
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    jfn = jax.jit(fn, donate_argnums=(0,))
    losses = []
    for s in range(16):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        state, m = jfn(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4]), losses
    # params stay sharded
    wq = state["params"]["layers"]["attn"]["wq"]["w"]
    assert not wq.sharding.is_fully_replicated
    print("OK", losses[0], "->", losses[-1])
    """
    out = _run_sub(code)
    assert "OK" in out


def test_sharded_quantized_decode_8dev():
    """Packed low-bit weights shard over the model axis and decode runs
    under pjit — the serving dry-run path at test scale."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.distributed.sharding import AxisPlan, named_sharding_tree, plan_scope
    from repro.models import api

    cfg = registry.get_reduced("qwen2-72b").replace(activation_dtype=jnp.float32)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = AxisPlan(mesh=mesh, batch=("data",), fsdp=None)
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    sh = named_sharding_tree(params, plan)
    params = jax.tree.map(jax.device_put, params, sh)
    caches = api.init_cache(cfg, 4, 32, dtype=jnp.float32)

    def decode(params, caches, tokens, pos):
        with plan_scope(plan):
            logits, nc, _ = api.forward(params, {"tokens": tokens}, cfg,
                                        caches=caches, cache_pos=pos)
            return logits[:, -1], nc

    toks = jnp.zeros((4, 1), jnp.int32)
    logits, caches = jax.jit(decode)(params, caches, toks, 0)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_pipeline_parallel_4stage():
    """GPipe pipeline == sequential stack on 4 pp-shards."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipelined_forward, split_stages

    mesh = jax.make_mesh((4,), ("pp",))
    L, D = 8, 16
    key = jax.random.key(0)
    ws = jax.random.normal(key, (L, D, D)) * (D ** -0.5)

    def layer(w, x):
        return jnp.tanh(x @ w)

    def stage_fn(stage_params, x):
        def body(c, w):
            return layer(w, c), None
        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    x = jax.random.normal(jax.random.key(1), (6, 4, D))  # [n_micro, mb, D]

    # sequential reference
    def seq(x2):
        def body(c, w):
            return layer(w, c), None
        y, _ = jax.lax.scan(body, x2, ws)
        return y
    want = jax.vmap(seq)(x)

    staged = split_stages({"w": ws}, 4)["w"]
    got = pipelined_forward(stage_fn, staged, x, mesh=mesh, n_stages=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    print("OK")
    """
    assert "OK" in _run_sub(code, devices=4)


def test_multipod_mesh_shapes():
    code = """
    import os
    from repro.launch.mesh import make_production_mesh, make_plan
    m1 = make_production_mesh()
    assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
    m2 = make_production_mesh(multi_pod=True)
    assert m2.devices.shape == (2, 16, 16)
    assert m2.axis_names == ("pod", "data", "model")
    plan = make_plan(m2)
    assert plan.batch == ("pod", "data")
    print("OK")
    """
    assert "OK" in _run_sub(code, devices=512)
