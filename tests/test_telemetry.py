"""Telemetry-layer tests: metrics registry, Chrome-trace recording and
validation, engine stats schema, dispatch profiling, monotonic clocks.

The contracts under test:
  * the registry's histograms are bounded (reservoir) but keep EXACT
    count/sum/min/max, and percentiles interpolate between closest ranks
    (the nearest-rank bug reported p95 of 3 samples as the max);
  * every trace the engine emits passes the Chrome-trace format invariants
    (X spans nest per track, async b/e balance per request id);
  * ``engine.stats()`` keeps its dict schema — every key present and
    finite on a fresh engine AND after a full serve, across dense/paged/
    spec/beam configurations;
  * telemetry never changes engine behaviour: tokens and sync counts are
    identical with and without a tracer;
  * heartbeat/interval math runs on the monotonic clock (wall-clock jumps
    must not fire timeouts).
"""

import json
import math
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.obs import dispatch as dispatch_obs
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               export_stats)
from repro.obs.trace import Tracer, load_trace, validate_chrome_trace
from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_and_gauge_basics():
    c = Counter("c_total")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("g")
    g.set(2.5)
    g.add(-1.0)
    assert g.value == 1.5
    with pytest.raises(ValueError):
        Counter("0bad name")


def test_histogram_bounded_reservoir_exact_aggregates():
    h = Histogram("h", reservoir_size=64)
    xs = np.arange(5000, dtype=float)
    for x in xs:
        h.observe(x)
    snap = h.snapshot()
    assert len(h._res) <= 64           # bounded however many observations
    assert snap["count"] == 5000       # aggregates stay exact
    assert snap["sum"] == pytest.approx(xs.sum())
    assert snap["min"] == 0.0 and snap["max"] == 4999.0
    assert snap["mean"] == pytest.approx(xs.mean())
    # reservoir percentiles approximate the population (uniform sample)
    assert 1000 < snap["p50"] < 4000


def test_histogram_interpolated_percentiles_match_numpy():
    """Small samples interpolate (numpy 'linear'), not nearest-rank."""
    h = Histogram("h2", reservoir_size=1024)
    for v in (10.0, 20.0, 30.0):
        h.observe(v)
    assert h.percentile(0.50) == pytest.approx(20.0)
    assert h.percentile(0.95) == pytest.approx(
        float(np.percentile([10, 20, 30], 95)))  # 29.0, NOT the max
    assert h.percentile(0.95) < 30.0
    h2 = Histogram("h3")
    for v in (1.0, 2.0, 3.0, 4.0):
        h2.observe(v)
    assert h2.percentile(0.5) == pytest.approx(2.5)
    with pytest.raises(ValueError):
        h2.percentile(1.5)


def test_empty_histogram_is_finite():
    h = Histogram("h4")
    snap = h.snapshot()
    for v in snap.values():
        if isinstance(v, float):
            assert math.isfinite(v)
    assert h.percentile(0.99) == 0.0 and h.mean == 0.0


def test_registry_get_or_create_and_kind_conflict():
    r = MetricsRegistry()
    c1 = r.counter("x_total")
    assert r.counter("x_total") is c1
    with pytest.raises(ValueError):
        r.histogram("x_total")


def test_registry_reset_prefix():
    r = MetricsRegistry()
    r.counter("engine_a").inc(3)
    r.counter("pool_b").inc(7)
    r.reset("engine_")
    assert r.get("engine_a").value == 0
    assert r.get("pool_b").value == 7


def test_prometheus_text_exposition():
    r = MetricsRegistry()
    r.set_common_labels(host="0")
    r.counter("req_total", help="requests").inc(2)
    h = r.histogram("lat_seconds", help="latency")
    h.observe(0.5)
    txt = r.prometheus_text()
    assert "# HELP req_total requests" in txt
    assert "# TYPE req_total counter" in txt
    assert 'req_total{host="0"} 2' in txt
    assert "# TYPE lat_seconds summary" in txt
    assert 'quantile="0.95"' in txt
    assert 'lat_seconds_count{host="0"} 1' in txt
    # snapshot is json-able
    json.dumps(r.snapshot())


def test_registry_thread_safety():
    """Concurrent writers never lose an update (per-instrument locks)."""
    r = MetricsRegistry()
    c = r.counter("n_total")
    h = r.histogram("v", reservoir_size=32)
    n_threads, per = 8, 2000

    def work(t):
        for i in range(per):
            c.inc()
            h.observe(float(i))

    ts = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per
    assert h.count == n_threads * per
    assert len(h._res) <= 32


def test_export_stats_flattens_nested_numbers():
    r = MetricsRegistry()
    n = export_stats(r, {"a": 1, "nested": {"b": 2.5, "skip": "str"},
                         "none": None, "flag": True}, prefix="eng")
    assert n == 2
    assert r.get("eng_a").value == 1.0
    assert r.get("eng_nested_b").value == 2.5
    assert r.get("eng_flag") is None  # bools/strings/None skipped


# ---------------------------------------------------------------------------
# tracer + chrome-trace validation
# ---------------------------------------------------------------------------

def test_tracer_spans_nest_and_validate():
    tr = Tracer(annotate_xla=False)
    with tr.span("outer", a=1):
        with tr.span("inner"):
            pass
        tr.instant("mark")
    tr.async_begin("request", id=7, mode="greedy")
    tr.async_end("request", id=7, tokens=3)
    doc = tr.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    summary = validate_chrome_trace(doc["traceEvents"])
    assert summary["by_phase"]["X"] == 2
    assert summary["by_phase"]["b"] == summary["by_phase"]["e"] == 1
    evs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    # inner nests strictly within outer on the same track
    assert evs["inner"]["ts"] >= evs["outer"]["ts"]
    assert (evs["inner"]["ts"] + evs["inner"]["dur"]
            <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1e-3)


def test_tracer_multithreaded_tracks():
    tr = Tracer(annotate_xla=False)
    # keep all threads alive until each has recorded: OS thread ids are
    # reused after exit, which would merge tracks
    barrier = threading.Barrier(3)

    def work():
        with tr.span("thread_span"):
            barrier.wait(timeout=10)

    ts = [threading.Thread(target=work) for _ in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    with tr.span("main_span"):
        pass
    summary = validate_chrome_trace(tr.chrome_trace()["traceEvents"])
    assert summary["tracks"] == 4  # one per thread


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x"):
        tr.instant("y")
    tr.async_begin("request", id=1)
    tr.async_end("request", id=1)
    assert len(tr) == 0


def test_validate_rejects_unbalanced_async():
    base = {"pid": 1, "tid": 1, "ts": 0.0}
    with pytest.raises(ValueError, match="begin without end"):
        validate_chrome_trace([
            dict(base, name="r", ph="b", cat="request", id=1)])
    with pytest.raises(ValueError, match="without begin"):
        validate_chrome_trace([
            dict(base, name="r", ph="e", cat="request", id=1)])


def test_validate_rejects_partial_overlap_and_missing_dur():
    base = {"pid": 1, "tid": 1, "cat": "c"}
    with pytest.raises(ValueError, match="must nest"):
        validate_chrome_trace([
            dict(base, name="a", ph="X", ts=0.0, dur=10.0),
            dict(base, name="b", ph="X", ts=5.0, dur=10.0)])
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace([dict(base, name="a", ph="X", ts=0.0)])
    with pytest.raises(ValueError, match="missing or mistyped"):
        validate_chrome_trace([{"name": "a", "ph": "X"}])


def test_trace_save_load_roundtrip(tmp_path):
    tr = Tracer(annotate_xla=False)
    with tr.span("s", k="v"):
        pass
    p = tr.save(str(tmp_path / "t.json"))
    evs = load_trace(p)
    validate_chrome_trace(evs)
    assert any(e["name"] == "s" and e["args"] == {"k": "v"} for e in evs)
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([1, 2]))
    with pytest.raises(ValueError, match="traceEvents"):
        load_trace(str(bad))


# ---------------------------------------------------------------------------
# dispatch profiling
# ---------------------------------------------------------------------------

def test_dispatch_recorder_dedup_and_summary():
    rec = dispatch_obs.DispatchRecorder()
    rec.record("dispatch", "k1", "fused", "auto", "heuristic", (8, 64, 4))
    rec.record("dispatch", "k1", "fused", "auto", "heuristic", (8, 64, 4))
    rec.record("dispatch", "k2", "staged", "tuned", "tuned", (8, 32, 4))
    rec.record("select_fusion", "d1", "fused", "auto", "heuristic")
    s = rec.summary()
    assert s["decisions"] == 2
    assert s["tuned"] == 1 and s["heuristic"] == 1 and s["forced"] == 0
    r1 = next(r for r in rec.records("dispatch") if r.key == "k1")
    assert r1.count == 2 and r1.block_n == 64


def test_recording_context_restores_previous():
    assert dispatch_obs.get_active() is None or True  # env-agnostic
    prev = dispatch_obs.get_active()
    with dispatch_obs.recording() as rec:
        assert dispatch_obs.get_active() is rec
        dispatch_obs.record("dispatch", "k", "fused", "auto", "heuristic")
        assert len(rec) == 1
    assert dispatch_obs.get_active() is prev


def test_resolve_dispatch_records_decision():
    from repro.kernels import ops
    with dispatch_obs.recording() as rec:
        fusion, bm, bn, bg = ops.resolve_dispatch(8, 64, 16, 4, 2)
    recs = rec.records("dispatch")
    assert len(recs) == 1
    r = recs[0]
    assert r.fusion == fusion and r.requested == "auto"
    assert r.source == "heuristic"
    assert (r.block_m, r.block_n, r.block_g) == (bm, bn, bg)
    # forced policy recorded as such
    with dispatch_obs.recording() as rec:
        ops.resolve_dispatch(8, 64, 16, 4, 2, fusion="staged")
    assert rec.records("dispatch")[0].source == "forced"


# ---------------------------------------------------------------------------
# engine integration: stats schema, trace schema, behaviour invariance
# ---------------------------------------------------------------------------

BASE_KEYS = {
    "decode_chunk", "prefill_chunk", "decode_syncs", "decode_tokens",
    "host_syncs_per_token", "prefill_dispatches", "p50_chunk_ms",
    "p95_chunk_ms", "decode_tok_s", "paged", "mesh", "cache_hbm_bytes",
    "slot_occupancy", "peak_active_slots", "admit_attempts", "admit_blocked",
    "admission_blocked_rate", "prefill_s", "prefill_tokens",
    "prefill_tokens_reused",
}


def _assert_finite(obj, path="stats"):
    if isinstance(obj, dict):
        for k, v in obj.items():
            _assert_finite(v, f"{path}.{k}")
    elif isinstance(obj, bool) or obj is None or isinstance(obj, str):
        pass
    elif isinstance(obj, (int, float, np.integer, np.floating)):
        assert np.isfinite(obj), f"non-finite {path} = {obj!r}"


def _cfg():
    cfg = registry.get_reduced("tinyllama-1.1b").replace(
        activation_dtype=jnp.float32)
    # packed store so the SAME params serve the spec-decoding config too
    return cfg.with_quant(mpgemm_mode="lut_xla", weight_bits=4,
                          store="packed", skip="lm_head")


@pytest.fixture(scope="module")
def tl():
    cfg = _cfg()
    params = api.init_params(jax.random.key(0), cfg, serve_quantized=True)
    return cfg, params


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)),
                         dtype=np.int32) for _ in range(n)]


ENGINE_CONFIGS = {
    "dense": (dict(), "greedy", set()),
    "paged": (dict(cache_block_size=8, prefix_cache=True), "greedy",
              {"cache_block_size", "num_cache_blocks", "blocks_in_use",
               "prefix_cache"}),
    "spec": (dict(spec_k=3, spec_draft_planes=2), "spec:draft2b", {"spec"}),
    "beam": (dict(), "beam:2", set()),
}


@pytest.mark.parametrize("name", sorted(ENGINE_CONFIGS))
def test_stats_schema_fresh_and_post_retire(tl, name):
    """Every stats key present and finite on a FRESH engine and after a
    full serve, for each engine configuration."""
    cfg, params = tl
    kw, dec, extra = ENGINE_CONFIGS[name]
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, decode_chunk=4,
                        prefill_chunk=4, **kw)
    fresh = eng.stats()
    assert BASE_KEYS <= set(fresh), BASE_KEYS - set(fresh)
    _assert_finite(fresh)
    assert fresh["decode_tok_s"] == 0.0 and fresh["p50_chunk_ms"] == 0.0

    for i, p in enumerate(_prompts(cfg, 3)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6, decoding=dec))
    eng.run_to_completion()
    st = eng.stats()
    assert (BASE_KEYS | extra) <= set(st), (BASE_KEYS | extra) - set(st)
    _assert_finite(st)
    assert st["decode_tokens"] > 0 and st["decode_tok_s"] > 0
    assert 0 < st["slot_occupancy"] <= 1.0
    if name == "spec":
        assert st["spec"]["verify_steps"] > 0
    if name == "paged":
        # only the prefix cache's own refs survive retirement
        assert st["blocks_in_use"] == len(eng._prefix)


def test_beam_group_visible_in_stats_mid_run(tl):
    cfg, params = tl
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, decode_chunk=2,
                        prefill_chunk=4)
    eng.submit(Request(uid=0, prompt=_prompts(cfg, 1)[0], max_new_tokens=8,
                       decoding="beam:2"))
    assert eng.step()  # admit + first chunk: group active
    st = eng.stats()
    assert st["beam"]["active_groups"] == 1
    _assert_finite(st)
    eng.run_to_completion()


def test_percentiles_interpolate_in_stats(tl):
    """The stats() percentile fix: p95 of 3 chunk latencies interpolates
    instead of snapping to the slowest chunk."""
    cfg, params = tl
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    for v in (0.010, 0.020, 0.030):
        eng._h_chunk_s.observe(v)
    st = eng.stats()
    assert st["p50_chunk_ms"] == pytest.approx(20.0)
    assert st["p95_chunk_ms"] == pytest.approx(29.0)  # nearest-rank gave 30


def test_engine_trace_schema(tl):
    """The trace a serve emits passes format validation and carries the
    span taxonomy: balanced per-request async spans, decode_chunk spans
    with occupancy attributes, prefill/admit spans."""
    cfg, params = tl
    tracer = Tracer(annotate_xla=False)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, decode_chunk=4,
                        prefill_chunk=4, cache_block_size=8,
                        prefix_cache=True, tracer=tracer)
    n_req = 3
    for i, p in enumerate(_prompts(cfg, n_req)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
    eng.submit(Request(uid=99, prompt=_prompts(cfg, 1)[0],
                       max_new_tokens=0))  # retires at admission
    eng.run_to_completion()

    evs = tracer.chrome_trace()["traceEvents"]
    summary = validate_chrome_trace(evs)
    # one balanced async request span per submitted request (incl. the
    # zero-budget one), matched by uid
    assert summary["by_phase"]["b"] == n_req + 1
    assert summary["by_phase"]["e"] == n_req + 1
    uids = {e["id"] for e in evs if e["ph"] == "b"}
    assert uids == {0, 1, 2, 99}
    chunks = [e for e in evs if e["name"] == "decode_chunk"]
    assert len(chunks) == eng.stats()["decode_syncs"]
    for c in chunks:
        assert 0 < c["args"]["occupancy"] <= 1.0
        assert c["args"]["active_slots"] >= 1
        assert c["args"]["steps"] == 4
    admits = [e for e in evs if e["name"] == "admit"]
    assert len(admits) == n_req + 1
    assert all(a["args"]["paged"] for a in admits)
    assert any(e["name"] == "prefill_chunk" for e in evs)


def test_tracing_does_not_change_behaviour(tl):
    """Same tokens, same sync count, with and without a tracer."""
    cfg, params = tl

    def serve(tracer):
        eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                            decode_chunk=4, prefill_chunk=4, tracer=tracer)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(_prompts(cfg, 3))]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.output for r in reqs], eng.stats()

    out_off, st_off = serve(None)
    out_on, st_on = serve(Tracer(annotate_xla=False))
    assert out_on == out_off
    assert st_on["decode_syncs"] == st_off["decode_syncs"]
    assert st_on["host_syncs_per_token"] == st_off["host_syncs_per_token"]


def test_engine_reset_zeroes_metric_series(tl):
    cfg, params = tl
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    for i, p in enumerate(_prompts(cfg, 2)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    eng.run_to_completion()
    assert eng._h_chunk_s.count > 0
    eng.reset()
    assert eng._h_chunk_s.count == 0
    st = eng.stats()
    assert st["decode_tok_s"] == 0.0 and st["slot_occupancy"] == 0.0


def test_tuning_cache_counters_in_stats(tl, tmp_path):
    cfg, params = tl
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        tuning_cache=str(tmp_path / "tc.json"))
    tc = eng.stats()["tuning_cache"]
    for k in ("entries", "hits", "misses", "sanitized", "foreign"):
        assert k in tc
    eng.tuning_cache.lookup("nonexistent-shape")
    assert eng.stats()["tuning_cache"]["misses"] >= 1


def test_metrics_snapshot_and_prometheus(tl):
    cfg, params = tl
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        cache_block_size=8, prefix_cache=True)
    for i, p in enumerate(_prompts(cfg, 2)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    eng.run_to_completion()
    snap = eng.metrics_snapshot()
    m = snap["metrics"]
    assert m["engine_decode_chunk_seconds"]["count"] == eng.decode_syncs
    assert m["blockpool_blocks_granted_total"]["value"] > 0
    assert m["prefix_cache_misses_total"]["value"] >= 0
    # stats() mirrored in as engine_* gauges
    assert m["engine_decode_tokens"]["value"] == eng.decode_tokens
    txt = eng.prometheus_text()
    assert "engine_decode_chunk_seconds_count" in txt
    assert "blockpool_blocks_in_use" in txt
    json.dumps(snap)  # json-able end to end


# ---------------------------------------------------------------------------
# monotonic-clock satellites
# ---------------------------------------------------------------------------

def test_heartbeat_uses_monotonic_not_wall_clock(monkeypatch):
    """A wall-clock jump must not fire heartbeat timeouts: the manager's
    default ``now`` comes from time.monotonic."""
    from repro.training import fault_tolerance as ft
    t = {"mono": 1000.0}
    monkeypatch.setattr(ft.time, "monotonic", lambda: t["mono"])
    # a wildly wrong wall clock must be irrelevant to interval math
    monkeypatch.setattr(ft.time, "time", lambda: 1e18)
    mgr = ft.FaultToleranceManager(2, heartbeat_timeout=10.0)
    assert mgr.dead_hosts() == []
    t["mono"] += 5.0
    mgr.heartbeat(0)
    t["mono"] += 7.0   # host 0 heartbeat 7s ago, host 1 12s ago
    assert mgr.dead_hosts() == [1]
    assert mgr.hosts[0].last_heartbeat == 1005.0


def test_checkpoint_manifest_wall_time_and_monotonic_duration(tmp_path):
    from repro.training import checkpoint as ck
    tree = {"w": jnp.ones((2, 2))}
    d = ck.save(str(tmp_path), 3, tree)
    with open(f"{d}/MANIFEST.json") as f:
        man = json.load(f)
    # wall-clock stays as metadata; the duration field is monotonic-derived
    assert man["time"] > 0
    assert man["write_seconds"] >= 0.0 and math.isfinite(man["write_seconds"])
    restored, step = ck.restore(str(tmp_path), tree)
    assert step == 3
