"""Training substrate tests: optimizers, checkpoint/restart, data pipeline,
gradient compression, end-to-end loss decrease."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.distributed import compression
from repro.training import checkpoint as C
from repro.training import optimizer as O
from repro.training.data import PackedCorpus, Prefetcher, SyntheticLM
from repro.training.train_loop import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _rosenbrockish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5 * jnp.sum((y - x ** 2) ** 2)


@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor", "momentum"])
def test_optimizer_converges(name):
    opt = O.make_optimizer(name, lr=3e-2 if name != "momentum" else 1e-3)
    params = {"x": jnp.zeros((4,)), "y": jnp.zeros((4,))}
    state = opt.init(params)
    loss0 = float(_rosenbrockish(params))

    @jax.jit
    def step(params, state):
        g = jax.grad(_rosenbrockish)(params)
        return opt.update(g, state, params)

    for _ in range(300):
        params, state = step(params, state)
    assert float(_rosenbrockish(params)) < 0.1 * loss0


def test_adamw8bit_state_is_int8():
    opt = O.make_optimizer("adamw8bit", lr=1e-3)
    params = {"w": jnp.ones((64, 64))}  # 4096 >= block size
    state = opt.init(params)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    g = {"w": jnp.full((64, 64), 0.1)}
    _, state = opt.update(g, state, params)
    assert state["m"]["w"]["q"].dtype == jnp.int8


def test_adafactor_state_is_factored():
    opt = O.make_optimizer("adafactor", lr=1e-3)
    params = {"w": jnp.ones((256, 512)), "b": jnp.ones((8,))}
    state = opt.init(params)
    assert state["v"]["w"]["vr"].shape == (256,)
    assert state["v"]["w"]["vc"].shape == (512,)
    assert state["v"]["b"]["v"].shape == (8,)  # small tensors unfactored


def test_lr_schedule():
    fn = O.lr_schedule(1.0, warmup=10, total=100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(fn(jnp.asarray(100))) < 1e-6


# ---------------------------------------------------------------------------
# checkpointing + restart
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    C.save(str(tmp_path), 7, tree)
    restored, step = C.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_and_gc(tmp_path):
    rm = C.RestartManager(str(tmp_path), every=1, keep=2, async_write=False)
    tree = {"x": jnp.zeros(3)}
    for s in range(1, 5):
        rm.maybe_save(s, {"x": jnp.full(3, float(s))})
    assert C.list_steps(str(tmp_path)) == [3, 4]  # gc keeps last 2
    restored, step = rm.restore_or_none(tree)
    assert step == 4 and float(restored["x"][0]) == 4.0
    # a stale .tmp dir must never be picked up
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert C.latest_step(str(tmp_path)) == 4


def test_restart_resumes_data_deterministically(tmp_path):
    src = SyntheticLM(100, 2, 8, seed=3)
    b5 = src.batch_at(5)
    b5_again = SyntheticLM(100, 2, 8, seed=3).batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])


def test_prefetcher_order():
    src = SyntheticLM(100, 2, 8, seed=1)
    pf = Prefetcher(src, start_step=0, depth=2)
    got = [pf.next()["tokens"] for _ in range(3)]
    pf.close()
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, src.batch_at(i)["tokens"])


def test_packed_corpus(tmp_path):
    toks = np.arange(1000, dtype=np.int32) % 50
    path = str(tmp_path / "corpus.npy")
    np.save(path, toks)
    pc = PackedCorpus(path, batch=2, seq_len=16, seed=0)
    b = pc.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_grad_compression_error_feedback():
    """EF accumulates the quantization residual; sum(compressed)+EF == signal."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    ef = compression.init_error_feedback(g)
    cg, ef2 = compression.compress_decompress_tree(g, ef)
    # lossy but residual-tracked: compressed + residual == original
    np.testing.assert_allclose(np.asarray(cg["w"]) + np.asarray(ef2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
    # int8 quantization error bounded by scale
    assert float(jnp.max(jnp.abs(ef2["w"]))) < float(jnp.max(jnp.abs(g["w"]))) / 100


# ---------------------------------------------------------------------------
# end-to-end: loss decreases with the QAT train step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "olmoe-1b-7b"])
def test_train_loss_decreases(arch):
    cfg = registry.get_reduced(arch).replace(activation_dtype=jnp.float32)
    opt = O.make_optimizer("adamw", lr=3e-3)
    step_fn = jax.jit(make_train_step(cfg, opt, qat=True), donate_argnums=(0,))
    state = init_train_state(jax.random.key(0), cfg, opt)
    data = SyntheticLM(cfg.vocab_size, 4, 32, seed=0)
    losses = []
    for s in range(30):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_train_with_microbatches_matches_full_batch():
    cfg = registry.get_reduced("tinyllama-1.1b").replace(
        activation_dtype=jnp.float32)
    opt = O.make_optimizer("momentum", lr=1e-2)
    data = SyntheticLM(cfg.vocab_size, 4, 16, seed=0)
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))

    s1 = init_train_state(jax.random.key(1), cfg, opt)
    s2 = jax.tree.map(lambda x: x, s1)
    f1 = jax.jit(make_train_step(cfg, opt, microbatches=1, qat=False))
    f2 = jax.jit(make_train_step(cfg, opt, microbatches=2, qat=False))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    # same averaged gradients => same params (fp tolerance)
    p1 = jax.tree_util.tree_leaves(s1["params"])
    p2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)
