"""Property-based tests (hypothesis) for the autotune dispatch layer:

  * block pickers (``ops.pick_blocks`` / ``ops._clamp_blocks``) always emit
    kernel-valid blocks — positive, packed-stream byte-aligned, within the
    LMMA VMEM budget — for adversarial shapes including odd group counts
    and non-power-of-two k_group;
  * tuned configs loaded from a foreign/adversarial cache are always either
    rejected or sanitized into valid candidates — ``fusion="tuned"``
    dispatch can never crash because of a cache file.

Deterministic durability/round-trip tests live in test_autotune.py (they
do not need hypothesis and must run even where it is absent).
"""

import pytest
import jax

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; "
    "pip install -r requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core import autotune, lmma
from repro.core.autotune import TunedConfig
from repro.kernels import ops

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

# adversarial shape axes: tiny/odd group counts, non-power-of-two k_group
m_st = st.integers(1, 300)
n_st = st.integers(1, 4096)
g_st = st.integers(1, 1024)
kg_st = st.sampled_from([1, 2, 3, 4, 5, 8])
planes_st = st.integers(1, 4)


def _assert_valid_blocks(bm, bn, bg, k_group, planes):
    assert isinstance(bm, int) and isinstance(bn, int) and isinstance(bg, int)
    assert bm >= 1 and bn >= 1 and bg >= 1
    # packed-stream byte alignment: every wrapper requires it
    assert (bg * planes * k_group) % 8 == 0


@given(m=m_st, n=n_st, g=g_st, kg=kg_st, planes=planes_st)
def test_pick_blocks_always_valid(m, n, g, kg, planes):
    """Scheduler-chosen blocks: positive, byte-aligned, VMEM-feasible."""
    bm, bn, bg = ops.pick_blocks(m, n, g, kg, planes)
    _assert_valid_blocks(bm, bn, bg, kg, planes)
    desc = lmma.LMMADescriptor(m=m, n=n, k=g * kg, w_bits=planes, k_group=kg)
    t, w, a = lmma._tile_bytes(min(bm, max(8, m)), min(bn, n),
                               min(bg, g), desc)
    assert 2 * (t + w) + a <= lmma.VMEM_BYTES


@given(m=m_st, n=n_st, g=g_st, kg=kg_st, planes=planes_st,
       block_m=st.one_of(st.none(), st.integers(1, 512)),
       block_n=st.one_of(st.none(), st.integers(1, 4096)),
       block_g=st.one_of(st.none(), st.integers(1, 1024)))
def test_clamp_blocks_always_valid(m, n, g, kg, planes,
                                   block_m, block_n, block_g):
    """Caller-pinned or scheduler blocks come out of the clamp valid, and
    auto_fusion resolves them to a real mode without crashing."""
    bm, bn, bg = ops._clamp_blocks(m, n, g, kg, planes,
                                   block_m, block_n, block_g)
    _assert_valid_blocks(bm, bn, bg, kg, planes)
    if block_m is not None:
        assert bm == block_m  # pinned knobs always win
    assert ops.auto_fusion(m, n, g, kg, planes, bm, bn, bg) in \
        ("fused", "staged")


adversarial_field = st.one_of(
    st.none(), st.booleans(), st.integers(-10, 10_000_000),
    st.floats(allow_nan=True, allow_infinity=True), st.text(max_size=8),
    st.sampled_from(["fused", "staged", "auto", "tuned", ""]))


@given(m=m_st, n=n_st, g=g_st, kg=kg_st, planes=planes_st,
       fusion=adversarial_field, bm=adversarial_field, bn=adversarial_field,
       bg=adversarial_field)
def test_sanitize_foreign_entry_never_invalid(m, n, g, kg, planes,
                                              fusion, bm, bn, bg):
    """Any cache entry — including one written by a different backend with
    arbitrary junk fields — sanitizes to None or a valid dispatch config."""
    cfg = TunedConfig(fusion=fusion, block_m=bm, block_n=bn, block_g=bg)
    out = autotune.sanitize_config(cfg, m, n, g, kg, planes)
    if out is None:
        return
    assert out.fusion in ("fused", "staged")
    _assert_valid_blocks(out.block_m, out.block_n, out.block_g, kg, planes)
    assert out.block_m <= max(8, m) and out.block_n <= max(1, n)
    if out.fusion == "fused":
        desc = lmma.LMMADescriptor(m=m, n=n, k=g * kg, w_bits=planes,
                                   k_group=kg)
        assert lmma.fused_tile_bytes(out.block_m, out.block_n, out.block_g,
                                     desc) <= lmma.VMEM_BYTES


@given(m=st.integers(1, 64), n=st.integers(1, 1024), g=st.integers(1, 256),
       kg=kg_st, planes=planes_st, fusion=adversarial_field,
       bm=adversarial_field, bn=adversarial_field, bg=adversarial_field)
def test_tuned_dispatch_never_crashes_on_bad_cache(m, n, g, kg, planes,
                                                   fusion, bm, bn, bg):
    """fusion="tuned" against an adversarial active cache resolves to a
    valid (fusion, blocks) decision — it degrades, never raises."""
    cache = autotune.configure(None)
    try:
        key = autotune.shape_key(m, n, g, kg, planes)
        cache.put(key, TunedConfig(fusion=fusion, block_m=bm,
                                   block_n=bn, block_g=bg))
        rf, rbm, rbn, rbg = ops.resolve_dispatch(m, n, g, kg, planes,
                                                 fusion="tuned")
        assert rf in ("fused", "staged")
        _assert_valid_blocks(rbm, rbn, rbg, kg, planes)
    finally:
        autotune.deactivate()
