# Single documented entry points for install / verify / benchmarks.
# ROADMAP.md's tier-1 command is `make test`.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: install test test-fast bench-smoke bench-serving bench-autotune \
	bench-distributed bench-decoding bench-telemetry

install:
	$(PYTHON) -m pip install -r requirements.txt

test:            ## tier-1 verify: the full suite, fail-fast
	$(PYTHON) -m pytest -x -q

test-fast:       ## kernel + core contracts only (minutes, not tens of)
	$(PYTHON) -m pytest -x -q tests/test_kernels.py tests/test_fused_mpgemm.py \
	    tests/test_lmma_dse.py tests/test_core_properties.py \
	    tests/test_autotune.py tests/test_autotune_properties.py \
	    tests/test_latency_regression.py tests/test_kvcache_paged.py \
	    tests/test_paged_serving.py

bench-smoke:     ## quick analytic benchmark pass (no kernels executed)
	$(PYTHON) benchmarks/bench_fused_mpgemm.py --smoke
	$(PYTHON) benchmarks/roofline_table.py 2>/dev/null || true

bench-serving:   ## serving-engine perf (chunked vs per-tick decode) -> JSON
	$(PYTHON) benchmarks/bench_serving.py --out BENCH_serving.json

bench-autotune:  ## measured-time kernel tuner vs LMMA heuristic -> JSON
	$(PYTHON) benchmarks/bench_autotune.py --cache .tuning_cache.json \
		--out BENCH_autotune.json

bench-distributed: ## tensor-parallel sharded decode vs dense -> JSON
	$(PYTHON) benchmarks/bench_distributed.py --mesh 2x4 \
		--out BENCH_distributed.json

bench-decoding:  ## beam + bit-plane self-speculation vs greedy -> JSON
	$(PYTHON) benchmarks/bench_decoding.py --reduced \
		--assert-spec-speedup 1.0 --out BENCH_decoding.json

bench-telemetry: ## telemetry overhead gate (tracing-on >= 0.97x off) -> JSON
	$(PYTHON) benchmarks/bench_telemetry.py --assert-overhead 0.97 \
		--out BENCH_telemetry.json
